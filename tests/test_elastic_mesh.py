"""Elastic DP: checkpoint -> remesh -> resharded restore continues
training with identical results (subprocess: needs >1 fake device)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow  # heavy sweep/compile module: excluded from tier-1

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_PROGRAM = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp, numpy as np
    from repro.config import TrainingConfig, get_arch
    from repro.distributed.elastic_mesh import mesh_for_devices, reshard_state
    from repro.distributed.param_shardings import make_rules, train_state_shardings, batch_shardings
    from repro.distributed.sharding import axis_rules
    from repro.models.zoo import build_model
    from repro.training.train_step import init_train_state, make_train_step

    cfg = get_arch("llama3.2-1b", smoke=True)
    tcfg = TrainingConfig(learning_rate=1e-3, warmup_steps=0, schedule="constant")
    model = build_model(cfg, compute_dtype=jnp.float32)
    step = make_train_step(model, tcfg)
    batch = {
        "tokens": jnp.tile(jnp.arange(32, dtype=jnp.int32)[None], (8, 1)) % cfg.vocab_size,
        "labels": jnp.tile(jnp.arange(1, 33, dtype=jnp.int32)[None], (8, 1)) % cfg.vocab_size,
    }

    def run_steps(state, mesh, n):
        rules = make_rules(cfg, mesh)
        with mesh, axis_rules(rules):
            jit_step = jax.jit(step)
            for _ in range(n):
                state, m = jit_step(state, batch)
        return state, float(m["loss"])

    # golden: 4 steps on mesh A (4 data x 2 model)
    mesh_a = mesh_for_devices(8, model_parallel=2)
    state = init_train_state(model, tcfg, jax.random.PRNGKey(0))
    state = reshard_state(state, cfg, mesh_a)
    golden, loss_g = run_steps(state, mesh_a, 4)

    # elastic: 2 steps on mesh A, "scale down" to mesh B (2 data x 2 model
    # — lost half the DP replicas), reshard, 2 more steps
    state = init_train_state(model, tcfg, jax.random.PRNGKey(0))
    state = reshard_state(state, cfg, mesh_a)
    state, _ = run_steps(state, mesh_a, 2)
    mesh_b = mesh_for_devices(4, model_parallel=2)
    state = reshard_state(state, cfg, mesh_b)
    state, loss_b = run_steps(state, mesh_b, 2)

    ok = True
    for a, b in zip(jax.tree.leaves(golden.params), jax.tree.leaves(state.params)):
        if not np.allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6):
            ok = False
    print("RESULT " + json.dumps({
        "match": ok, "loss_golden": loss_g, "loss_elastic": loss_b,
        "mesh_a": str(mesh_a.shape), "mesh_b": str(mesh_b.shape),
    }))
""")


def test_remesh_preserves_training_trajectory():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", _PROGRAM],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    assert out["match"], out
    assert abs(out["loss_golden"] - out["loss_elastic"]) < 1e-4

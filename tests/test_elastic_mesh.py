"""Elastic DP: checkpoint -> remesh -> resharded restore continues
training with identical results (subprocess: needs >1 fake device)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow  # heavy sweep/compile module: excluded from tier-1

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_PROGRAM = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp, numpy as np
    from repro.config import TrainingConfig, get_arch
    from repro.distributed.elastic_mesh import mesh_for_devices, reshard_state
    from repro.distributed.param_shardings import make_rules, train_state_shardings, batch_shardings
    from repro.distributed.sharding import axis_rules
    from repro.models.zoo import build_model
    from repro.training.train_step import init_train_state, make_train_step

    cfg = get_arch("llama3.2-1b", smoke=True)
    tcfg = TrainingConfig(learning_rate=1e-3, warmup_steps=0, schedule="constant")
    model = build_model(cfg, compute_dtype=jnp.float32)
    step = make_train_step(model, tcfg)
    batch = {
        "tokens": jnp.tile(jnp.arange(32, dtype=jnp.int32)[None], (8, 1)) % cfg.vocab_size,
        "labels": jnp.tile(jnp.arange(1, 33, dtype=jnp.int32)[None], (8, 1)) % cfg.vocab_size,
    }

    def run_steps(state, mesh, n):
        rules = make_rules(cfg, mesh)
        with mesh, axis_rules(rules):
            jit_step = jax.jit(step)
            for _ in range(n):
                state, m = jit_step(state, batch)
        return state, float(m["loss"])

    # golden: 4 steps on mesh A (4 data x 2 model)
    mesh_a = mesh_for_devices(8, model_parallel=2)
    state = init_train_state(model, tcfg, jax.random.PRNGKey(0))
    state = reshard_state(state, cfg, mesh_a)
    golden, loss_g = run_steps(state, mesh_a, 4)

    # elastic: 2 steps on mesh A, "scale down" to mesh B (2 data x 2 model
    # — lost half the DP replicas), reshard, 2 more steps
    state = init_train_state(model, tcfg, jax.random.PRNGKey(0))
    state = reshard_state(state, cfg, mesh_a)
    state, _ = run_steps(state, mesh_a, 2)
    mesh_b = mesh_for_devices(4, model_parallel=2)
    state = reshard_state(state, cfg, mesh_b)
    state, loss_b = run_steps(state, mesh_b, 2)

    ok = True
    for a, b in zip(jax.tree.leaves(golden.params), jax.tree.leaves(state.params)):
        if not np.allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6):
            ok = False
    print("RESULT " + json.dumps({
        "match": ok, "loss_golden": loss_g, "loss_elastic": loss_b,
        "mesh_a": str(mesh_a.shape), "mesh_b": str(mesh_b.shape),
    }))
""")


def _run_program(program: str) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", program],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_remesh_preserves_training_trajectory():
    out = _run_program(_PROGRAM)
    assert out["match"], out
    assert abs(out["loss_golden"] - out["loss_elastic"]) < 1e-4


_ROUNDTRIP_PROGRAM = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp, numpy as np
    from repro.config import TrainingConfig, get_arch
    from repro.distributed.elastic_mesh import dp_degree, mesh_for_devices, reshard_state
    from repro.distributed.param_shardings import make_rules, train_state_shardings
    from repro.models.zoo import build_model
    from repro.training.train_step import init_train_state

    cfg = get_arch("llama3.2-1b", smoke=True)
    tcfg = TrainingConfig(learning_rate=1e-3, warmup_steps=0, schedule="constant")
    model = build_model(cfg, compute_dtype=jnp.float32)
    state = init_train_state(model, tcfg, jax.random.PRNGKey(0))
    baseline = [np.asarray(x) for x in jax.tree.leaves(state)]

    ok_bits, ok_shard, degrees = True, True, []
    for dp in (1, 2, 4, 3):
        mesh = mesh_for_devices(dp, model_parallel=1)
        degrees.append(dp_degree(mesh))
        state = reshard_state(state, cfg, mesh)
        # every leaf bitwise equal to the original host values...
        for ref, leaf in zip(baseline, jax.tree.leaves(state)):
            if not np.array_equal(ref, np.asarray(leaf)):
                ok_bits = False
        # ...and laid out with exactly the sharding this mesh implies
        rules = make_rules(cfg, mesh)
        expected = train_state_shardings(state, cfg, mesh, rules)
        for leaf, want in zip(jax.tree.leaves(state), jax.tree.leaves(expected)):
            if not leaf.sharding.is_equivalent_to(want, leaf.ndim):
                ok_shard = False
    print("RESULT " + json.dumps(
        {"bitwise": ok_bits, "shardings": ok_shard, "degrees": degrees}))
""")


def test_reshard_state_roundtrip_1_2_4_3_bitwise_and_sharded():
    """Property/regression (ISSUE 3 satellite): a TrainState round-
    tripped across DP degrees 1 -> 2 -> 4 -> 3 keeps every leaf bitwise
    identical and lands with the sharding each new mesh implies."""
    out = _run_program(_ROUNDTRIP_PROGRAM)
    assert out["degrees"] == [1, 2, 4, 3]
    assert out["bitwise"], "resharding altered tensor bits"
    assert out["shardings"], "a leaf kept a stale sharding after remesh"


_ELASTIC_JOB_PROGRAM = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp, numpy as np
    from repro.config import TrainingConfig, get_arch
    from repro.core.elastic import AutoscalerConfig
    from repro.data.pipeline import build_token_log
    from repro.models.zoo import build_model
    from repro.training.job import TrainingJob

    cfg = get_arch("llama3.2-1b", smoke=True)
    tcfg = TrainingConfig(learning_rate=1e-3, warmup_steps=0, schedule="constant")
    model = build_model(cfg, compute_dtype=jnp.float32)
    log = build_token_log(cfg.vocab_size, 256, doc_len=33, partitions=4)
    job = TrainingJob(
        model, cfg, tcfg, log, batch_size=8, seq_len=32, dp=2, max_dp=4,
        elastic=True, use_mesh=True, model_parallel=1,
        autoscaler=AutoscalerConfig(
            min_workers=2, max_workers=4, high_watermark=2.0,
            low_watermark=0.1, cooldown=2.0, step_fraction=1.0,
        ),
    )
    start_mesh = dict(job.mesh.shape)
    final = job.run(20)
    consumed = sum(job.committed_offsets().values())
    # per-step offset deltas must tile the stream exactly (no skip/double)
    prev, gapfree = {}, True
    for step in range(1, final + 1):
        offs = job.step_offsets[step]
        for p, off in offs.items():
            if off <= prev.get(p, 0):
                gapfree = False
            prev[p] = off
    print("RESULT " + json.dumps({
        "final": final,
        "start_mesh": start_mesh,
        "end_mesh": dict(job.mesh.shape),
        "scale_log": [[o, n, m] for (_, o, n, m) in job.scale_log],
        "scale_events": len(job.pool.controller.scale_events),
        "consumed": consumed,
        "gapfree": gapfree,
        "workers": len(job.pool.active_workers()),
        "loss_finite": bool(np.isfinite(job.losses[-1])),
    }))
""")


def test_autoscaler_reshards_dp_2_to_4_mid_run():
    """ACCEPTANCE (ISSUE 3): the queue-depth autoscaler's decision
    actuates through the pool's on_scale hook as mesh_for_devices at the
    new DP degree + reshard_state, mid-run, without losing stream
    position."""
    out = _run_program(_ELASTIC_JOB_PROGRAM)
    assert out["final"] == 20
    assert out["start_mesh"] == {"data": 2, "model": 1}
    assert out["end_mesh"]["data"] == 4, out
    assert out["scale_events"] >= 1
    assert any(o == 2 and n == 4 for (o, n, m) in out["scale_log"]), out
    # scale happened mid-run and the stream position was exact:
    # 20 steps x 8 docs, no gaps, no double consumption
    assert out["consumed"] == 160
    assert out["gapfree"], "a step skipped or re-consumed an offset"
    assert out["workers"] == 4
    assert out["loss_finite"]

"""The shared ElasticPool runtime: generic worker-pool mechanics, the
bounded-mailbox scale-in/restart overflow fix, and lossless CRDT
telemetry across chaos kills (paper §3.2.2–§3.2.4)."""

import itertools

import pytest

from repro.core.elastic import AutoscalerConfig
from repro.core.messages import Mailbox, MailboxOverflow, Message
from repro.core.pool import DedupWindow, ElasticPool, WorkerBase
from repro.core.reactive import ReactiveJob
from repro.data.topics import MessageLog
from repro.telemetry.metrics import MetricsHub


class EchoWorker(WorkerBase):
    """Minimal pool worker: consumes its mailbox, records payloads."""

    _ids = itertools.count()

    def __init__(self, sink, budget=4, capacity=0):
        super().__init__(f"echo{next(EchoWorker._ids)}",
                         mailbox_capacity=capacity)
        self.sink = sink
        self.budget = budget

    def step(self, now: float = 0.0) -> int:
        n = 0
        while n < self.budget and self.alive:
            msg = self.mailbox.get()
            if msg is None:
                break
            self.sink.append(msg.payload)
            self.metrics.incr("task.processed")
            n += 1
        return n


def fill(log: MessageLog, topic: str, n: int, partitions: int = 3) -> None:
    if not log.exists(topic):
        log.create_topic(topic, partitions)
    for i in range(n):
        log.publish(topic, payload=i)


# --- generic pool mechanics ---------------------------------------------------


def test_pool_dispatches_ingress_to_workers():
    sink = []
    pool = ElasticPool("p", lambda: EchoWorker(sink), initial_units=3,
                       ingress_capacity=0, elastic=False)
    for i in range(12):
        assert pool.offer(Message(topic="t", payload=i))
    for t in range(4):
        pool.step(float(t))
    assert sorted(sink) == list(range(12))
    assert pool.counter("pool.admitted") == 12
    assert pool.counter("task.processed") == 12


def test_pool_bounded_ingress_shed_and_defer_feed_autoscaler():
    sink = []
    pool = ElasticPool("p", lambda: EchoWorker(sink, budget=0),
                       initial_units=1, ingress_capacity=2, overflow="shed",
                       autoscaler=AutoscalerConfig(
                           high_watermark=1.0, low_watermark=-1.0,
                           cooldown=0.0, max_workers=4),
                       max_workers=4)
    accepted = [pool.offer(Message(topic="t", payload=i)) for i in range(6)]
    assert sum(accepted) == 2
    assert len(pool.shed) == 4
    assert pool.counter("pool.shed") == 4
    # rejected demand reaches the controller even though the ingress is full
    pool.step(0.0)
    assert pool.target_units() > 1
    assert pool.counter("pool.scale_out") >= 1

    defer = ElasticPool("q", lambda: EchoWorker(sink, budget=0),
                        initial_units=1, ingress_capacity=1, overflow="defer")
    assert defer.offer(Message(topic="t", payload=0))
    assert not defer.offer(Message(topic="t", payload=1))
    assert not defer.shed  # defer never drops: the caller owns the retry
    assert defer.counter("pool.deferred") == 1


def test_pool_unknown_overflow_and_retire_mode_rejected():
    with pytest.raises(ValueError):
        ElasticPool("p", lambda: EchoWorker([]), overflow="explode")
    with pytest.raises(ValueError):
        ElasticPool("p", lambda: EchoWorker([]), retire_mode="vanish")


def test_pool_kill_worker_readmits_without_loss():
    sink = []
    pool = ElasticPool("p", lambda: EchoWorker(sink, budget=1),
                       initial_units=2, ingress_capacity=0,
                       elastic=False, heartbeat_timeout=2.0)
    for i in range(10):
        pool.offer(Message(topic="t", payload=i))
    pool.step(0.0)
    killed = pool.kill_worker(0)
    now = 1.0
    for _ in range(40):
        if pool.queue_depth() == 0:
            break
        pool.step(now)
        now += 1.0
    assert sorted(sink) == list(range(10))
    assert pool.counter("pool.worker_restarts") == 1
    assert pool.counter("pool.readmitted") > 0
    assert any(e[1] == "restarted" and e[2] == killed
               for e in pool.supervisor.events)


def test_route_with_all_workers_dead_parks_message():
    """route() with every worker dead must not crash the *sender*: the
    message parks in a dead worker's mailbox and survives until the
    supervisor's restart drain (it is never lost)."""
    from repro.core.virtual_messaging import VirtualProducerGroup
    from repro.data.topics import Topic

    out = Topic("out", 1)
    pg = VirtualProducerGroup(out, initial_size=1)
    pg.producers[0].alive = False
    pg.submit(Message(topic="out", payload=1))  # must not raise
    assert pg.pending() == 1
    pg.step_all()
    assert out.total_messages() == 0  # dead producer does not publish
    pg.producers[0].alive = True
    pg.step_all()
    assert out.total_messages() == 1


def test_dedup_window_bounded():
    d = DedupWindow(window=4)
    assert not d.seen(1)
    assert d.seen(1)
    for k in range(2, 8):
        d.seen(k)
    assert len(d) <= 5  # overflow dropped the oldest half
    assert not d.seen(1)  # evicted: counts as new again (at-least-once)


# --- the scale-in / restart overflow fix --------------------------------------


def test_bounded_mailbox_scale_in_8_to_1_does_not_overflow():
    """Regression (ISSUE 2 satellite): retiring tasks used Mailbox.put to
    redistribute drained messages, which raised MailboxOverflow when the
    survivors' bounded mailboxes were already full — crashing scale-in
    mid-drain.  Now the drain spills overflow-safely and nothing is
    lost."""
    log = MessageLog()
    fill(log, "in", 120, partitions=3)
    seen = []
    job = ReactiveJob(
        "j", log, "in", lambda m: (seen.append(m.payload), [])[1],
        initial_tasks=8,
        mailbox_capacity=2,
        batch_n=40,
        autoscaler=AutoscalerConfig(
            # low_watermark above any realistic backlog: every observation
            # demands scale-in, so 8 tasks collapse toward 1 while their
            # bounded mailboxes are still loaded.
            high_watermark=1e9, low_watermark=1e9,
            min_workers=1, max_workers=8, cooldown=0.0, step_fraction=1.0,
        ),
    )
    t = 0.0
    for _ in range(400):
        t += 1.0
        job.step(now=t, task_budget=1)
        if job.backlog() == 0:
            break
    assert len(job.tasks) == 1  # scaled all the way in under load
    assert sorted(seen) == sorted(range(120))  # nothing lost, nothing doubled
    assert job.total_processed() == 120


def test_bounded_mailbox_restart_does_not_overflow():
    """A task killed while its bounded mailbox is full (plus put_front
    overage) must restart without raising: pending messages move to the
    fresh instance, overflow spills to the survivors."""
    log = MessageLog()
    fill(log, "in", 60, partitions=3)
    seen = []
    job = ReactiveJob(
        "j", log, "in", lambda m: (seen.append(m.payload), [])[1],
        initial_tasks=4, mailbox_capacity=2, batch_n=30,
        heartbeat_timeout=2.0, elastic=False,
    )
    job.step(now=0.0, task_budget=1)
    victim = job.tasks[0]
    victim.mailbox.put_front(Message(topic="in", payload=999))  # over the bound
    victim.alive = False
    t = 0.0
    for _ in range(400):
        t += 1.0
        job.step(now=t, task_budget=1)
        if job.backlog() == 0:
            break
    assert job.backlog() == 0
    assert sorted(p for p in seen if p != 999) == sorted(range(60))
    assert 999 in seen  # the over-bound message survived the restart too


# --- CRDT telemetry through the unified pool ----------------------------------


def test_reactive_job_metrics_merge_losslessly_across_chaos_kill():
    """ReactiveJob now emits CRDT telemetry via ElasticPool (it emitted
    none before the re-base): admission/restart/processed counters from
    live workers, dead workers (graveyard), and the pool replica merge
    losslessly into a MetricsHub across a chaos kill."""
    log = MessageLog()
    fill(log, "in", 120, partitions=3)
    job = ReactiveJob("j", log, "in", lambda m: [m.payload],
                      out_topic=None, initial_tasks=4, heartbeat_timeout=2.0)
    job.step(now=0.0)
    job.tasks[0].alive = False  # chaos kill mid-stream
    t = 0.0
    for _ in range(400):
        t += 1.0
        job.step(now=t)
        if job.backlog() == 0:
            break
    assert any(e[1] == "restarted" for e in job.supervisor.events)

    hub = MetricsHub()
    # Merge in arbitrary pieces, twice (merge is commutative/idempotent —
    # re-merging a restarted worker's replica must not double-count).
    for task in job.tasks:
        hub.ingest(task.metrics)
    hub.ingest(job.pool.graveyard)
    hub.ingest(job.pool.metrics)
    hub.ingest(job.pool.merged_metrics())  # everything again, at once
    assert hub.counter("task.processed") == 120 == job.total_processed()
    assert hub.counter("job.task_restarts") == 1
    assert hub.counter("job.task_spawns") >= 4


def test_serving_pool_metrics_merge_losslessly_across_chaos_kill(tmp_path):
    import jax

    from repro.models.stub import StubModel
    from repro.serving import ElasticServingPool, Request

    model = StubModel()
    params = model.init(jax.random.PRNGKey(0))
    pool = ElasticServingPool(model, params, slots_per_replica=2,
                              max_replicas=2, initial_units=4,
                              heartbeat_timeout=2.0)
    for i in range(12):
        pool.submit(Request(prompt=[i % 5 + 1], max_new_tokens=6), now=0.0)
    now = 1.0
    for _ in range(3):
        pool.step(now)
        now += 1.0
    pool.kill_replica(0)
    for _ in range(100):
        if pool.queue_depth() == 0 and pool.occupancy() == 0:
            break
        pool.step(now)
        now += 1.0
    assert len(pool.completed) == 12

    hub = MetricsHub()
    hub.ingest(pool.pool.graveyard)
    for replica in pool.replicas:
        hub.ingest(replica.metrics)
    hub.ingest(pool.metrics)
    hub.ingest(pool.pool.merged_metrics())  # idempotent re-merge
    assert hub.counter("serve.admitted") == 12
    assert hub.counter("serve.completed") == 12
    assert hub.counter("serve.replica_kills") == 1
    assert hub.counter("serve.replica_restarts") == 1
    assert hub.counter("serve.readmitted") > 0
    # scale counters flow through the same replica set
    assert hub.counter("serve.scale_in") + hub.counter("serve.scale_out") >= 1


# --- live worker handoff (ISSUE 8) --------------------------------------------


class CarryWorker(WorkerBase):
    """Worker that holds processed results in-worker until an external
    collector takes them — the pattern where a kill between process and
    collect would otherwise force a recompute.  ``sink`` records every
    *compute* event, so recomputation is observable."""

    _ids = itertools.count()

    def __init__(self, sink, budget=8):
        super().__init__(f"carry{next(CarryWorker._ids)}")
        self.sink = sink
        self.budget = budget
        self.results = []

    def step(self, now: float = 0.0) -> int:
        n = 0
        while n < self.budget and self.alive:
            msg = self.mailbox.get()
            if msg is None:
                break
            self.sink.append(msg.payload)
            self.results.append(Message(topic="r", payload=msg.payload))
            n += 1
        return n

    def export_carry(self):
        out, self.results = self.results, []
        return out

    def import_carry(self, msgs):
        self.results.extend(msgs)
        return len(msgs)


def test_worker_handoff_carries_results_and_filters_readmission():
    """A chaos-killed worker's processed-but-uncollected results ride
    the handoff channel to its replacement instead of being recomputed,
    and an at-least-once redelivery of a carried key is filtered out of
    readmission (no double-apply)."""
    from repro.checkpoint.handoff import WorkerHandoffChannel

    log = MessageLog()
    channel = WorkerHandoffChannel(log, key_fn=lambda m: m.payload)
    sink = []
    pool = ElasticPool("p", lambda: CarryWorker(sink, budget=5),
                       initial_units=1, ingress_capacity=0, elastic=False,
                       heartbeat_timeout=2.0, handoff=channel)
    # 5 distinct payloads + a duplicate delivery of payload 2
    for payload in (0, 1, 2, 3, 4, 2):
        pool.offer(Message(topic="t", payload=payload))
    pool.step(0.0)  # budget 5: results 0-4 held in-worker, dup 2 queued
    assert sink == [0, 1, 2, 3, 4]
    killed = pool.kill_worker(0)
    now = 1.0
    for _ in range(10):
        pool.step(now)
        now += 1.0
    assert any(e[1] == "restarted" and e[2] == killed
               for e in pool.supervisor.events)
    # carried, not recomputed: the 5 results live in the fresh worker
    # and the compute log shows no second pass
    fresh = pool.workers[0]
    assert sorted(m.payload for m in fresh.results) == [0, 1, 2, 3, 4]
    assert sink == [0, 1, 2, 3, 4]
    # the redelivered payload-2 message was filtered from readmission
    assert fresh.mailbox.depth() == 0
    assert channel.carried == 5 and channel.recovered == 5
    assert pool.counter("pool.worker_handoffs") == 1
    assert pool.counter("pool.handoff_carried") == 5


def test_worker_handoff_marks_done_exactly_once():
    """Recovered keys are acknowledged: a second restart cannot re-adopt
    results the previous replacement already imported."""
    from repro.checkpoint.handoff import WorkerHandoffChannel

    log = MessageLog()
    channel = WorkerHandoffChannel(log, key_fn=lambda m: m.payload)
    sink = []
    pool = ElasticPool("p", lambda: CarryWorker(sink, budget=4),
                       initial_units=1, ingress_capacity=0, elastic=False,
                       heartbeat_timeout=2.0, handoff=channel)
    for payload in range(4):
        pool.offer(Message(topic="t", payload=payload))
    pool.step(0.0)
    pool.kill_worker(0)
    now = 1.0
    for _ in range(10):
        pool.step(now)
        now += 1.0
    assert channel.recovered == 4
    assert channel.recover() == {}  # all carried keys are marked done
    # kill the replacement too: it carries the same 4 results forward
    pool.kill_worker(0)
    for _ in range(10):
        pool.step(now)
        now += 1.0
    assert channel.carried == 8 and channel.recovered == 8
    assert sorted(m.payload for m in pool.workers[0].results) == [0, 1, 2, 3]
    assert sink == [0, 1, 2, 3]  # still exactly one compute per payload

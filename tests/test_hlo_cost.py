"""Loop-aware HLO cost model: the scan-undercount fix and its invariants
(compiled.cost_analysis() counts while bodies once — see hlo_cost.py)."""

import jax
import jax.numpy as jnp
import pytest

from repro.roofline.hlo_cost import analyze_hlo


def _scan_fn(n_layers):
    def body(x, w):
        return jnp.dot(x, w), None

    def fn(x, w):
        y, _ = jax.lax.scan(body, x, w)
        return y

    return fn


def _compile_text(fn, *avals):
    return jax.jit(fn).lower(*avals).compile().as_text()


def test_scan_flops_scale_with_trip_count():
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    f8 = analyze_hlo(_compile_text(
        _scan_fn(8), x, jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)))
    f16 = analyze_hlo(_compile_text(
        _scan_fn(16), x, jax.ShapeDtypeStruct((16, 256, 256), jnp.float32)))
    expected8 = 8 * 2 * 128 * 256 * 256
    assert f8.flops == pytest.approx(expected8, rel=0.01)
    assert f16.flops == pytest.approx(2 * expected8, rel=0.01)
    assert 8 in f8.while_trip_counts.values()
    assert 16 in f16.while_trip_counts.values()


def test_grad_of_scan_is_3x_forward():
    """fwd+bwd of a matmul chain costs 3x the forward (classic identity)."""
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)

    fwd = analyze_hlo(_compile_text(_scan_fn(8), x, w))

    def loss(x_, w_):
        return jnp.sum(_scan_fn(8)(x_, w_) ** 2)

    bwd = analyze_hlo(_compile_text(jax.grad(loss, argnums=1), x, w))
    assert bwd.flops / fwd.flops == pytest.approx(3.0, rel=0.05)


def test_unrolled_matches_scanned_flops():
    """Same math, scan vs python-unrolled: counted FLOPs must agree."""
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((4, 128, 128), jnp.float32)

    def unrolled(x_, w_):
        for i in range(4):
            x_ = jnp.dot(x_, w_[i])
        return x_

    a = analyze_hlo(_compile_text(_scan_fn(4), x, w))
    b = analyze_hlo(_compile_text(unrolled, x, w))
    assert a.flops == pytest.approx(b.flops, rel=0.01)


def test_bytes_grow_with_trips():
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    f4 = analyze_hlo(_compile_text(
        _scan_fn(4), x, jax.ShapeDtypeStruct((4, 256, 256), jnp.float32)))
    f32 = analyze_hlo(_compile_text(
        _scan_fn(32), x, jax.ShapeDtypeStruct((32, 256, 256), jnp.float32)))
    assert f32.bytes > 4 * f4.bytes  # roughly linear in depth


def test_no_dots_no_flops():
    x = jax.ShapeDtypeStruct((128,), jnp.float32)
    c = analyze_hlo(_compile_text(lambda v: v * 2 + 1, x))
    assert c.flops == 0
    assert c.bytes > 0  # elementwise traffic still counted

"""Log-backed serving (ISSUE 2 tentpole): the requests topic + virtual
consumer group feed the elastic pool, offsets commit only after
completion, responses are durable, and the whole pool can be killed and
rebuilt from the log with exactly-once completion."""

import os

import jax
import jax.numpy as jnp
import pytest

from repro.core.messages import Message
from repro.data.topics import MessageLog
from repro.models.stub import StubModel
from repro.serving import Request, ServingJob


@pytest.fixture(scope="module")
def stub():
    model = StubModel()
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def greedy_reference(model, params, prompt, n_new):
    toks = list(prompt)
    out = []
    for _ in range(n_new):
        logits, _ = model.train_logits(
            params, {"tokens": jnp.asarray(toks, dtype=jnp.int32)[None]}
        )
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        toks.append(nxt)
    return out


# --- messaging-layer spill ----------------------------------------------------


def test_message_log_spill_and_reopen(tmp_path):
    d = str(tmp_path / "log")
    log = MessageLog(spill_dir=d)
    log.create_topic("t", 2)
    for i in range(10):
        log.publish("t", payload={"i": i}, key=str(i % 3), created_at=float(i))
    before = [
        [(m.offset, m.payload, m.key) for m in p.read(0, 100)]
        for p in log.get("t").partitions
    ]
    log.close()

    re = MessageLog.reopen(d)
    after = [
        [(m.offset, m.payload, m.key) for m in p.read(0, 100)]
        for p in re.get("t").partitions
    ]
    assert after == before
    # appends continue past the recovered offsets, onto the same files
    p, off = re.publish("t", payload={"i": 99})
    assert off == re.get("t").partitions[p].end_offset() - 1
    re2 = MessageLog.reopen(d)
    assert re2.get("t").total_messages() == 11


def test_message_log_reopen_without_manifest_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        MessageLog.reopen(str(tmp_path / "nothing-here"))


def test_spill_requires_json_payloads(tmp_path):
    log = MessageLog(spill_dir=str(tmp_path / "log"))
    log.create_topic("t", 1)
    with pytest.raises(TypeError):
        log.get("t").publish(Message(topic="t", payload=object()))


# --- log-backed serving -------------------------------------------------------


def make_job(stub, **kwargs):
    model, params = stub
    defaults = dict(partitions=2, slots_per_replica=2, max_replicas=2,
                    initial_units=2, heartbeat_timeout=3.0)
    defaults.update(kwargs)
    return ServingJob(model, params, **defaults)


def test_log_backed_serving_completes_all(stub):
    model, params = stub
    job = make_job(stub)
    reqs = [Request(prompt=[i % 5 + 1], max_new_tokens=5) for i in range(8)]
    for r in reqs:
        job.submit(r, now=0.0)
    job.run_until_drained(now=1.0)
    resp = job.responses()
    assert sorted(r["req_id"] for r in resp) == sorted(r.req_id for r in reqs)
    for r in resp:
        assert r["output"] == greedy_reference(model, params, r["prompt"], 5)
    # commit-after-complete: every partition fully committed once drained
    assert job.request_lag() == 0
    for c in job.consumers.consumers:
        assert c.offset == job.requests_topic.partitions[c.partition].end_offset()


def test_log_backed_bounded_ingress_backpressures_not_sheds(stub):
    """A bounded pool ingress pushes back on the virtual consumers (they
    re-read the suffix later); nothing is ever shed in log-backed mode —
    the log is the buffer."""
    job = make_job(stub, ingress_capacity=2)
    reqs = [Request(prompt=[i % 5 + 1], max_new_tokens=4) for i in range(12)]
    for r in reqs:
        job.submit(r, now=0.0)
    job.run_until_drained(now=1.0)
    assert len(job.responses()) == 12
    assert job.metrics.value("serve.shed") == 0
    assert not job.pool.shed


def test_log_backed_bounded_ingress_still_scales_out(stub):
    """Backlog parked in the requests topic behind a full ingress must
    reach the autoscaler as rejected demand — otherwise a bounded ingress
    pins the pool at its initial size exactly when scale-out is needed."""
    job = make_job(stub, ingress_capacity=4, initial_units=1)
    for i in range(40):
        job.submit(Request(prompt=[i % 5 + 1], max_new_tokens=6), now=0.0)
    now = 1.0
    for _ in range(6):
        job.step(now)
        now += 1.0
    assert job.request_lag() > 0, "the bounded ingress must be the bottleneck"
    assert job.pool.target_units() > 1
    assert len(job.pool.controller.scale_events) >= 1
    job.run_until_drained(now=now)
    assert len(job.responses()) == 40


def test_new_requests_after_process_restart_get_fresh_ids(stub):
    """A restarted process restarts the Request id counter at 0; without
    the reopen-time bump, new submissions would collide with ids already
    answered in the durable log and be silently swallowed as replays."""
    import itertools

    import repro.serving.batcher as batcher_mod

    model, params = stub
    job1 = make_job(stub)
    first = [Request(prompt=[i % 5 + 1], max_new_tokens=4) for i in range(6)]
    for r in first:
        job1.submit(r, now=0.0)
    job1.run_until_drained(now=1.0)
    assert len(job1.responses()) == 6

    # "process restart": the module counter starts over, the log survives
    saved = batcher_mod._req_ids
    batcher_mod._req_ids = itertools.count()
    try:
        job2 = make_job(stub, log=job1.log)
        fresh = [Request(prompt=[i % 5 + 1], max_new_tokens=4) for i in range(3)]
        assert all(r.req_id not in job2.responded for r in fresh), \
            "reopen must bump the id counter past the durable log"
        for r in fresh:
            job2.submit(r, now=50.0)
        job2.run_until_drained(now=51.0)
        resp_ids = [r["req_id"] for r in job2.responses()]
        for r in fresh:
            assert resp_ids.count(r.req_id) == 1
        assert len(resp_ids) == 9
    finally:
        batcher_mod._req_ids = saved


def test_log_backed_replica_chaos_kill_exactly_once(stub):
    job = make_job(stub, initial_units=4, heartbeat_timeout=2.0)
    reqs = [Request(prompt=[i % 5 + 1], max_new_tokens=8) for i in range(10)]
    for r in reqs:
        job.submit(r, now=0.0)
    now = 1.0
    for _ in range(4):
        job.step(now)
        now += 1.0
    job.kill_replica(0)
    for _ in range(200):
        if job.pending() == 0:
            break
        job.step(now)
        now += 1.0
    ids = [r["req_id"] for r in job.responses()]
    assert sorted(ids) == sorted(r.req_id for r in reqs)
    assert len(ids) == len(set(ids))
    assert job.metrics.value("serve.replica_restarts") == 1


def test_full_process_failure_replays_from_log_exactly_once(stub, tmp_path):
    """Acceptance: kill the ENTIRE pool (simulated process death — the
    first job is simply abandoned), rebuild from the spilled requests
    topic + committed offset journals, and every request completes
    exactly once across the two lives, token-exact."""
    model, params = stub
    d = str(tmp_path / "serve-log")
    jdir = os.path.join(d, "journals")
    job1 = make_job(stub, spill_dir=d, journal_dir=jdir, ingress_capacity=4)
    # Two long-running head requests block each partition's commit
    # watermark while short tail requests complete out of order — so
    # responses exist whose offsets cannot commit yet, exactly the window
    # where naive replay would double-execute.  Explicit req_ids pin the
    # key-hash partition placement (the global id counter would make
    # phase-1 progress depend on suite ordering).
    reqs = [
        Request(prompt=[i % 5 + 1], max_new_tokens=24 if i < 2 else 4,
                req_id=1_000_000 + i)
        for i in range(12)
    ]
    for r in reqs:
        job1.submit(r, now=0.0)
    now = 1.0
    for _ in range(10):  # partial progress, then the process "dies"
        job1.step(now)
        now += 1.0
    phase1 = len(job1.responses())
    assert 0 < phase1 < len(reqs), "kill must land mid-flight"
    job1.close()  # process exit; in-heap state (ingress, replicas) is GONE

    log2 = MessageLog.reopen(d)
    job2 = make_job(stub, log=log2, journal_dir=jdir, ingress_capacity=4)
    # the rebuilt consumers resume from the committed offsets...
    assert job2.committed_offsets() == job1.committed_offsets()
    # ...and the uncommitted suffix replays
    assert job2.request_lag() > 0
    job2.run_until_drained(now=100.0)

    resp = job2.responses()  # durable across both lives
    ids = [r["req_id"] for r in resp]
    assert sorted(set(ids)) == sorted(r.req_id for r in reqs)
    assert len(ids) == len(set(ids)), "a request completed twice"
    # the dedup window did real work: at least one phase-1 response sat
    # above an uncommitted offset and was skipped (not re-decoded) on replay
    assert job2.metrics.value("serve.replay_deduped") >= 1
    by_id = {r["req_id"]: r for r in resp}
    for req in reqs:
        out = by_id[req.req_id]["output"]
        assert out == greedy_reference(
            model, params, req.prompt, req.max_new_tokens
        )
    # everything committed in the second life
    for c in job2.consumers.consumers:
        assert c.offset == job2.requests_topic.partitions[c.partition].end_offset()

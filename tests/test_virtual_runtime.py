"""One actuator, two clocks: a job driven tick-by-tick via ``step(now)``
and the same job driven by ``VirtualRuntime`` on the ``SimEngine`` event
heap must be indistinguishable — bitwise-identical committed offsets and
processed counters.  This equivalence is what makes the virtual-clock
paper figures statements about the shipped system."""

from repro.core.cluster import Cluster, FailureConfig, FailureInjector, StepCost
from repro.core.dataflow import Stage, StageGraph
from repro.core.elastic import AutoscalerConfig
from repro.core.reactive import ReactiveJob
from repro.core.runtime import VirtualRuntime
from repro.data.topics import MessageLog


def build_graph(messages=240, cluster=None):
    log = MessageLog()
    for t in ("in", "mid", "out"):
        log.create_topic(t, 3)
    for i in range(messages):
        log.publish("in", payload=i)
    graph = StageGraph(log, throttle_low=8, throttle_high=32)
    graph.add(Stage(
        "first", log, "in", "mid", process=lambda m: [m.payload + 1],
        initial_tasks=2, batch_n=8, heartbeat_timeout=2.0,
        autoscaler=AutoscalerConfig(high_watermark=8, low_watermark=1,
                                    min_workers=1, max_workers=6, cooldown=3.0),
        cluster=cluster, restart_cost=1.0,
        step_cost=StepCost(t_process0=0.05), consume_cost=0.01,
    ))
    graph.add(Stage(
        "second", log, "mid", "out", process=lambda m: [m.payload * 2],
        initial_tasks=2, batch_n=8, heartbeat_timeout=2.0,
        autoscaler=AutoscalerConfig(high_watermark=8, low_watermark=1,
                                    min_workers=1, max_workers=4, cooldown=3.0),
        cluster=cluster, restart_cost=1.0,
        step_cost=StepCost(t_process0=0.02), consume_cost=0.01,
    ))
    return graph


def state_of(graph):
    return {
        "offsets": graph.committed_offsets(),
        "processed": {
            name: s.pool.work_done for name, s in graph.stages.items()
        },
        "counters": {
            name: (s.pool.counter("task.processed"),
                   s.pool.counter("stage.published"))
            for name, s in graph.stages.items()
        },
        "targets": {
            name: s.pool.controller.target_size
            for name, s in graph.stages.items()
        },
    }


DT = 0.25
TICKS = 480  # 120 s of virtual time


def test_stage_graph_hand_stepped_equals_virtual_runtime():
    # hand-stepped: the plain for-loop every test in the repo uses
    hand = build_graph()
    now = 0.0
    for _ in range(TICKS):
        hand.step(now)
        now += DT

    # event-heap: VirtualRuntime schedules the same ticks
    heap = build_graph()
    rt = VirtualRuntime(heap, dt=DT)
    rt.run_until((TICKS - 1) * DT)

    assert state_of(hand) == state_of(heap)
    # and the run actually did something end-to-end
    assert state_of(hand)["counters"]["second"][0] == 240
    assert sorted(heap.stage("second").outputs()) == sorted(
        (i + 1) * 2 for i in range(240)
    )


def test_equivalence_holds_under_cluster_chaos():
    """Same equivalence with placement, node failure, and relocation in
    the loop: the failure events ride the heap at tick-aligned times, so
    the hand-stepped twin injects them between the same ticks."""
    fc = FailureConfig(probability=0.5, interval=10.0, restart_delay=5.0, seed=4)

    def run_hand():
        cluster = Cluster(3, cores=2)
        graph = build_graph(cluster=cluster)
        # a private engine pumps the injector between hand-driven ticks
        rt = VirtualRuntime(graph, dt=DT)  # engine only; ticks unused
        injector = FailureInjector(rt.engine, cluster, fc)
        now = 0.0
        for _ in range(TICKS):
            rt.engine.run_until(now)   # fire failure events due by `now`
            graph.step(now)
            now += DT
        return graph, injector

    def run_heap():
        cluster = Cluster(3, cores=2)
        graph = build_graph(cluster=cluster)
        rt = VirtualRuntime(graph, dt=DT)
        injector = FailureInjector(rt.engine, cluster, fc)
        rt.run_until((TICKS - 1) * DT)
        return graph, injector

    hand_graph, hand_inj = run_hand()
    heap_graph, heap_inj = run_heap()
    assert hand_inj.failures == heap_inj.failures > 0
    assert state_of(hand_graph) == state_of(heap_graph)
    assert state_of(hand_graph)["counters"]["second"][0] == 240


def test_reactive_job_equivalence():
    def build():
        log = MessageLog()
        log.create_topic("stream", 3)
        for i in range(150):
            log.publish("stream", payload=i)
        return ReactiveJob(
            "eq", log, "stream", process=lambda m: [],
            initial_tasks=3, batch_n=8,
            step_cost=StepCost(t_process0=0.05), consume_cost=0.005,
        )

    hand = build()
    now = 0.0
    for _ in range(TICKS):
        hand.step(now)
        now += DT

    heap = build()
    VirtualRuntime(heap, dt=DT).run_until((TICKS - 1) * DT)

    assert hand.total_processed() == heap.total_processed() == 150
    assert (hand.stage.committed_offsets() == heap.stage.committed_offsets())
    assert hand.stage.completions == heap.stage.completions

"""Blockwise (flash-recurrence, jnp) attention == dense attention, across
masks/windows/GQA, plus full-model equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_arch
from repro.config.base import ArchConfig, AttentionKind, FFNKind, LayerSpec
from repro.models.layers import (
    attention,
    attention_implementation,
    init_attention,
)
from repro.models.zoo import build_model

pytestmark = pytest.mark.slow  # heavy sweep/compile module: excluded from tier-1

K = jax.random.PRNGKey


def mini_cfg(h=4, kv=2, hd=16, window=0):
    kind = AttentionKind.SLIDING if window else AttentionKind.FULL
    return (
        ArchConfig(
            name="t", family="dense", num_layers=1, d_model=64,
            num_heads=h, num_kv_heads=kv, d_ff=128, vocab_size=64,
            head_dim=hd,
            pattern=(LayerSpec(attention=kind, ffn=FFNKind.DENSE, window=window),),
        ),
        LayerSpec(attention=kind, ffn=FFNKind.DENSE, window=window),
    )


@pytest.mark.parametrize("window", [0, 64])
@pytest.mark.parametrize("t", [128, 200])  # incl. non-multiple of block
def test_blockwise_matches_dense(t, window):
    cfg, spec = mini_cfg(window=window)
    params = init_attention(K(0), cfg, jnp.float32)
    x = jax.random.normal(K(1), (2, t, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(t)[None], (2, t))
    y_dense, _ = attention(params, x, pos, cfg, spec)
    with attention_implementation("blockwise", block=64):
        y_blk, _ = attention(params, x, pos, cfg, spec)
    np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_blk),
                               rtol=2e-5, atol=2e-5)


def test_blockwise_with_cache_decode_matches_dense():
    cfg, spec = mini_cfg()
    from repro.models.layers import init_attention_cache

    params = init_attention(K(0), cfg, jnp.float32)
    S = 160
    cache = init_attention_cache(cfg, 2, S, jnp.float32)
    # prefill 100 tokens dense
    x = jax.random.normal(K(1), (2, 100, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(100)[None], (2, 100))
    _, cache = attention(params, x, pos, cfg, spec, cache=cache)
    # decode 1 token both ways
    xd = jax.random.normal(K(2), (2, 1, cfg.d_model))
    posd = jnp.full((2, 1), 100)
    y_dense, _ = attention(params, xd, posd, cfg, spec, cache=cache)
    with attention_implementation("blockwise", block=64):
        y_blk, _ = attention(params, xd, posd, cfg, spec, cache=cache)
    np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_blk),
                               rtol=2e-5, atol=2e-5)


def test_full_model_logits_same_under_blockwise():
    cfg = get_arch("gemma3-4b", smoke=True)  # local:global mix
    model = build_model(cfg, compute_dtype=jnp.float32)
    params = model.init(K(0))
    toks = jax.random.randint(K(1), (2, 96), 0, cfg.vocab_size)
    l_dense, _ = model.train_logits(params, {"tokens": toks})
    with attention_implementation("blockwise", block=32):
        l_blk, _ = model.train_logits(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(l_dense), np.asarray(l_blk),
                               rtol=5e-4, atol=5e-4)

"""Multi-stage reactive dataflow (ISSUE 4 tentpole): a ``StageGraph`` of
ElasticPools over durable topics — chained commit-after-publish,
end-to-end exactly-once across worker chaos kills AND full-process
death, keyed re-partitioning, topic fan-out, upstream backpressure, and
the bounded-dedup-memory invariant."""

import os

import pytest

from repro.core.dataflow import Stage, StageGraph
from repro.core.elastic import AutoscalerConfig
from repro.core.pool import DedupWindow
from repro.core.simulation import (
    SimStageConfig,
    WorkloadConfig,
    simulate_dataflow,
)
from repro.core.state import EventJournal
from repro.data.topics import MessageLog, partition_for_key
from tests._hypothesis_support import given, settings, st


def fill(log, topic, n, partitions=3, keyed=False):
    if not log.exists(topic):
        log.create_topic(topic, partitions)
    for i in range(n):
        log.publish(topic, payload=i, key=(str(i) if keyed else None))


def chain3(log, graph_kwargs=None, stage_kwargs=None, journal_dir=None):
    """in -> (+1) -> mid1 -> (*2) -> mid2 -> (-3) -> out."""
    for t, p in (("in", 3), ("mid1", 3), ("mid2", 3), ("out", 3)):
        if not log.exists(t):
            log.create_topic(t, p)
    graph = StageGraph(log, **(graph_kwargs or {}))
    fns = [lambda m: [m.payload + 1], lambda m: [m.payload * 2],
           lambda m: [m.payload - 3]]
    topics = ["in", "mid1", "mid2", "out"]
    for i, fn in enumerate(fns):
        kw = dict(initial_tasks=2, heartbeat_timeout=2.0, batch_n=8)
        kw.update(stage_kwargs or {})
        if journal_dir is not None:
            os.makedirs(journal_dir, exist_ok=True)
            topic = topics[i]
            kw["journal_factory"] = (
                lambda p, t=topic: EventJournal(
                    os.path.join(journal_dir, f"{t}-p{p}.journal")
                )
            )
        graph.add(Stage(f"s{i}", log, topics[i], topics[i + 1],
                        process=fn, **kw))
    return graph


def expected_outputs(n):
    return sorted((i + 1) * 2 - 3 for i in range(n))


def terminal_values(graph):
    return sorted(graph.stage("s2").outputs())


def assert_fully_committed(graph):
    for s in graph.stages.values():
        for c in s.consumers.consumers:
            assert c.offset == s.in_topic.partitions[c.partition].end_offset(), (
                s.name, c.partition
            )


# --- basic chains -------------------------------------------------------------


def test_three_stage_chain_exactly_once():
    log = MessageLog()
    fill(log, "in", 90)
    graph = chain3(log)
    graph.run_to_completion()
    assert terminal_values(graph) == expected_outputs(90)
    assert_fully_committed(graph)
    # per-stage counts: every stage processed and published exactly once
    for s in graph.stages.values():
        assert s.pool.counter("task.processed") == 90
        assert s.pool.counter("stage.published") == 90


def test_keyed_repartition_preserves_per_key_partition():
    """Keyed outputs land in the partition the key hashes to — the
    inter-stage re-partitioning contract (fan-in stays ordered per
    key)."""
    log = MessageLog()
    log.create_topic("in", 2)
    log.create_topic("out", 4)
    for i in range(40):
        log.publish("in", payload=i, key=str(i))
    graph = StageGraph(log)
    graph.add(Stage("s", log, "in", "out",
                    process=lambda m: [m.payload],
                    key_fn=lambda v: f"k{v % 5}"))
    graph.run_to_completion()
    out = log.get("out")
    assert out.total_messages() == 40
    for p_idx, part in enumerate(out.partitions):
        for msg in part.read(0, 1000):
            assert msg.key is not None
            assert partition_for_key(msg.key, out.num_partitions) == p_idx


def test_fanout_two_stages_one_topic():
    """Kafka-style fan-out: two stages subscribe the same intermediate
    topic with independent consumer groups; both see every message."""
    log = MessageLog()
    fill(log, "in", 30)
    log.create_topic("mid", 3)
    log.create_topic("outA", 1)
    log.create_topic("outB", 1)
    graph = StageGraph(log)
    graph.add(Stage("head", log, "in", "mid", process=lambda m: [m.payload]))
    graph.add(Stage("a", log, "mid", "outA", process=lambda m: [m.payload + 100]))
    graph.add(Stage("b", log, "mid", "outB", process=lambda m: [m.payload + 200]))
    graph.run_to_completion()
    assert sorted(graph.stage("a").outputs()) == sorted(i + 100 for i in range(30))
    assert sorted(graph.stage("b").outputs()) == sorted(i + 200 for i in range(30))
    assert_fully_committed(graph)


def test_fan_in_two_stages_one_downstream_topic():
    """Two upstream stages publish the same downstream topic; the
    consumer stage sees each exactly once (publish dedup is per-stage,
    keyed by (stage, partition, offset))."""
    log = MessageLog()
    fill(log, "inA", 20, partitions=2)
    fill(log, "inB", 20, partitions=2)
    log.create_topic("mid", 3)
    log.create_topic("out", 1)
    graph = StageGraph(log)
    graph.add(Stage("a", log, "inA", "mid", process=lambda m: [("a", m.payload)]))
    graph.add(Stage("b", log, "inB", "mid", process=lambda m: [("b", m.payload)]))
    graph.add(Stage("sink", log, "mid", "out", process=lambda m: [m.payload]))
    graph.run_to_completion()
    out = [tuple(v) for v in graph.stage("sink").outputs()]
    assert sorted(out) == sorted(
        [("a", i) for i in range(20)] + [("b", i) for i in range(20)]
    )


# --- chaos: worker kills at every stage ---------------------------------------


def test_chain_kill_middle_stage_workers_exactly_once():
    """Acceptance drill, part 1: chaos-kill the *middle* stage's workers
    mid-run; the supervisor heals the stage and every input still
    produces exactly one terminal output, with per-stage committed
    offsets reaching the end of every topic."""
    log = MessageLog()
    fill(log, "in", 120)
    graph = chain3(log)
    now = 0.0
    for _ in range(3):
        graph.step(now)
        now += 1.0
    graph.kill_stage("s1")  # every middle-stage worker at once
    for _ in range(600):
        graph.step(now)
        now += 1.0
        if graph.pending() == 0:
            break
    graph.step(now)
    assert terminal_values(graph) == expected_outputs(120)
    assert_fully_committed(graph)
    assert graph.stage("s1").pool.counter("stage.task_restarts") >= 1
    # zero-skip / zero-double per stage: every intermediate topic holds
    # each (stage, partition, offset) source exactly once
    for topic in ("mid1", "mid2", "out"):
        srcs = [
            m.src for p in log.get(topic).partitions for m in p.read(0, 10_000)
        ]
        assert len(srcs) == len(set(srcs)) == 120


def test_chain_kill_every_stage_in_turn():
    log = MessageLog()
    fill(log, "in", 90)
    graph = chain3(log)
    now = 0.0
    for kill_tick, name in ((2, "s0"), (6, "s1"), (10, "s2")):
        while now <= kill_tick:
            graph.step(now)
            now += 1.0
        graph.kill_worker(name, 0)
    for _ in range(600):
        graph.step(now)
        now += 1.0
        if graph.pending() == 0:
            break
    graph.step(now)
    assert terminal_values(graph) == expected_outputs(90)
    assert_fully_committed(graph)


def test_chain_virtual_consumer_crash_no_duplicates():
    """A crashed virtual consumer restarts from the *committed* offset
    and re-reads the forwarded-but-uncommitted suffix; stage-level
    admission dedup keeps processing exactly-once anyway."""
    log = MessageLog()
    fill(log, "in", 90)
    graph = chain3(log)
    graph.step(0.0)
    vc = graph.stage("s0").consumers.consumers[0]
    vc.alive = False  # crash: stops consuming AND heartbeating
    now = 1.0
    for _ in range(600):
        graph.step(now)
        now += 1.0
        if graph.pending() == 0:
            break
    graph.step(now)
    assert terminal_values(graph) == expected_outputs(90)
    for s in graph.stages.values():
        assert s.pool.counter("task.processed") == 90


# --- chaos: full-process death ------------------------------------------------


def test_full_process_death_replays_exactly_once(tmp_path):
    """Acceptance drill, part 2: kill the whole process mid-run (abandon
    the graph), rebuild from the spilled topics + committed offset
    journals, drain — terminal outputs are exactly-once and identical to
    an uninterrupted run, and per-stage committed offsets match the
    uninterrupted run bitwise."""
    def build(spill_dir, journal_dir):
        manifest = os.path.join(spill_dir, "topics.json")
        if os.path.exists(manifest):
            log = MessageLog.reopen(spill_dir)
        else:
            log = MessageLog(spill_dir=spill_dir)
            fill(log, "in", 100)
        return log, chain3(log, journal_dir=journal_dir,
                           stage_kwargs={"mailbox_capacity": 4, "batch_n": 4})

    # Reference: uninterrupted run on its own spill dir.
    ref_dir = str(tmp_path / "ref")
    ref_log, ref = build(ref_dir, os.path.join(ref_dir, "j"))
    ref.run_to_completion()
    ref_outputs = terminal_values(ref)
    ref_offsets = ref.committed_offsets()
    assert ref_outputs == expected_outputs(100)

    # Chaos: partial progress, then the process "dies" (objects dropped).
    # One straggler worker per stage pins each partition's commit
    # watermark behind faster workers' completions — so at death time
    # there are outputs durably published above uncommitted offsets,
    # exactly the window where naive replay would double-execute.
    d = str(tmp_path / "chaos")
    jdir = os.path.join(d, "j")
    log1, g1 = build(d, jdir)
    for s in g1.stages.values():
        s.pool.workers[0].step_budget = 1
    now = 0.0
    for _ in range(6):
        g1.step(now)
        now += 1.0
    done_phase1 = len(g1.stage("s2").outputs())
    assert 0 < done_phase1 < 100, "the kill must land mid-flight"
    committed1 = g1.committed_offsets()
    g1.close()
    log1.close()  # process exit; in-heap state (mailboxes, pools) is GONE

    log2, g2 = build(d, jdir)
    # rebuilt consumers resume from the committed offsets...
    assert g2.committed_offsets() == committed1
    # ...and at least one stage has an uncommitted suffix to replay
    assert sum(s.input_lag() for s in g2.stages.values()) > 0
    g2.run_to_completion(now=100.0)

    assert terminal_values(g2) == ref_outputs
    assert g2.committed_offsets() == ref_offsets
    assert_fully_committed(g2)
    # replay was dedup'd, not re-executed, wherever outputs already
    # existed above an uncommitted offset
    replayed = sum(
        s.pool.counter("stage.replay_deduped") for s in g2.stages.values()
    )
    assert replayed >= 1
    # zero-skip/zero-double: each topic holds every source exactly once
    for topic in ("mid1", "mid2", "out"):
        srcs = [
            m.src for p in log2.get(topic).partitions for m in p.read(0, 10_000)
        ]
        assert len(srcs) == len(set(srcs)) == 100


# --- backpressure -------------------------------------------------------------


def make_throttle_graph(backpressure, n=300):
    log = MessageLog()
    fill(log, "in", n)
    log.create_topic("mid", 3)
    log.create_topic("out", 3)
    graph = StageGraph(log, backpressure=backpressure,
                       throttle_low=8, throttle_high=32)
    fast = AutoscalerConfig(high_watermark=4.0, low_watermark=0.5,
                            min_workers=1, max_workers=16, cooldown=0.0)
    slow_scaler = AutoscalerConfig(high_watermark=4.0, low_watermark=0.5,
                                   min_workers=1, max_workers=2, cooldown=0.0)
    graph.add(Stage("fast", log, "in", "mid", process=lambda m: [m.payload],
                    autoscaler=fast, mailbox_capacity=4))
    graph.add(Stage("slow", log, "mid", "out", process=lambda m: [m.payload],
                    autoscaler=slow_scaler, mailbox_capacity=2,
                    step_budget=1))
    return graph


def test_backpressure_bounds_intermediate_topic_lag():
    """The throttle experiment: a capacity-limited slow stage behind a
    fast stage.  With backpressure the fast stage is throttled (its unit
    target capped) and the intermediate topic's peak lag stays well
    below the no-backpressure run's."""
    on = make_throttle_graph(True)
    off = make_throttle_graph(False)
    for g in (on, off):
        now = 0.0
        for _ in range(60):
            g.step(now)
            now += 1.0
    peak_on = on.peak_lag("slow")
    peak_off = off.peak_lag("slow")
    assert on.stage("fast").pool.counter("stage.throttled") >= 1
    assert off.stage("fast").pool.counter("stage.throttled") == 0
    assert peak_on < peak_off, (peak_on, peak_off)
    # drain both: throttling must not lose anything
    for g in (on, off):
        g.run_to_completion(now=100.0)
        assert sorted(g.stage("slow").outputs()) == sorted(range(300))


def test_throttle_freeze_band_blocks_scale_out():
    """Regression: with downstream pressure inside [throttle_low,
    throttle_high) the upstream unit target must FREEZE — the cap is
    evaluated before the autoscaler's decision, so scale-out into a
    drowning consumer is suppressed, not rubber-stamped."""
    log = MessageLog()
    fill(log, "in", 400)
    log.create_topic("mid", 3)
    log.create_topic("out", 3)
    # throttle_high effectively unreachable: only the freeze band acts
    graph = StageGraph(log, backpressure=True,
                       throttle_low=4, throttle_high=10_000)
    graph.add(Stage("fast", log, "in", "mid",
                    process=lambda m: [m.payload], mailbox_capacity=4,
                    autoscaler=AutoscalerConfig(
                        high_watermark=2.0, low_watermark=0.0,
                        min_workers=1, max_workers=16, cooldown=0.0)))
    graph.add(Stage("slow", log, "mid", "out",
                    process=lambda m: [m.payload], mailbox_capacity=2,
                    step_budget=1, elastic=False, initial_tasks=1))
    fast = graph.stage("fast")
    now = 0.0
    frozen_at = None
    for _ in range(40):
        graph.step(now)
        now += 1.0
        pressure = graph.stage("slow").pending()
        if frozen_at is None and 4 <= pressure < 10_000:
            frozen_at = fast.pool.target_units()
        elif frozen_at is not None:
            assert fast.pool.target_units() <= frozen_at, \
                "freeze band let the target grow"
    assert frozen_at is not None, "pressure never entered the freeze band"
    assert fast.pool.counter("stage.throttled") >= 1
    graph.run_to_completion(now=now)
    assert sorted(graph.stage("slow").outputs()) == sorted(range(400))


def test_throttle_caps_target_units():
    on = make_throttle_graph(True)
    now = 0.0
    peak_target = 0
    for _ in range(40):
        on.step(now)
        now += 1.0
        peak_target = max(peak_target, on.stage("fast").pool.target_units())
        if on.stage("fast").pool.counter("stage.throttled"):
            break
    # once throttled the fast stage's target collapses toward 1
    for _ in range(5):
        on.step(now)
        now += 1.0
    assert on.stage("fast").pool.target_units() <= peak_target


# --- simulate_dataflow --------------------------------------------------------


def test_simulate_dataflow_chain_and_backpressure():
    wl = WorkloadConfig(total_messages=6000, partitions=3, batch_n=10,
                        t_consume=0.0005, t_process0=0.02)
    fast = AutoscalerConfig(high_watermark=16, low_watermark=2,
                            min_workers=1, max_workers=12, cooldown=10.0)
    slow = AutoscalerConfig(high_watermark=32, low_watermark=2,
                            min_workers=1, max_workers=2, cooldown=20.0)
    stages = [
        SimStageConfig("a", t_process0=0.02, autoscaler=fast),
        SimStageConfig("b", t_process0=0.05, autoscaler=slow),
        SimStageConfig("c", t_process0=0.002),
    ]
    on = simulate_dataflow(stages, wl, duration=120.0, backpressure=True)
    off = simulate_dataflow(stages, wl, duration=120.0, backpressure=False)
    assert on.throttle_events > 0 and off.throttle_events == 0
    assert on.peak_lag(1) < off.peak_lag(1)
    # determinism: same config, same result
    again = simulate_dataflow(stages, wl, duration=120.0, backpressure=True)
    assert again.terminal.processed == on.terminal.processed
    assert again.peak_lag(1) == on.peak_lag(1)


def test_simulate_dataflow_mid_chain_kill_loses_time_not_messages():
    wl = WorkloadConfig(total_messages=2000, partitions=3, batch_n=10,
                        t_consume=0.0005, t_process0=0.005)
    stages = [SimStageConfig("a"), SimStageConfig("b"), SimStageConfig("c")]
    clean = simulate_dataflow(stages, wl, duration=300.0)
    killed = simulate_dataflow(stages, wl, duration=300.0,
                               kill_stage_at=(5.0, 1), restart_cost=10.0)
    assert killed.stages[1].restarts >= 1
    assert killed.terminal.processed == clean.terminal.processed == 2000


# --- dedup-memory bound (satellite) -------------------------------------------


def test_dedup_window_watermark_eviction_unit():
    d = DedupWindow()
    for p in range(2):
        for o in range(10):
            assert not d.seen((p, o))
    assert len(d) == 20
    dropped = d.evict_below({0: 5, 1: 10})
    assert dropped == 15
    assert len(d) == 5
    assert d.seen((0, 7))  # survivors still known
    assert not d.seen((1, 3))  # evicted: counts as new again


def test_dedup_window_memo_roundtrip():
    d = DedupWindow()
    assert not d.seen("k")
    d.remember("k", [1, 2])
    assert d.seen("k")
    assert d.lookup("k") == [1, 2]
    d.remember("missing", "x")  # no-op for unseen keys
    assert d.lookup("missing") is None


def test_stage_dedup_memory_stays_bounded_by_uncommitted_suffix():
    """Long chaos run: the stage's dedup structures (publish window,
    admitted set, worker windows) are evicted below the committed
    watermark every commit, so they track the uncommitted suffix — not
    the full history."""
    log = MessageLog()
    fill(log, "in", 600, partitions=2)
    log.create_topic("out", 2)
    graph = StageGraph(log)
    stage = graph.add(Stage("s", log, "in", "out",
                            process=lambda m: [m.payload],
                            initial_tasks=3, heartbeat_timeout=2.0,
                            batch_n=16))
    now = 0.0
    peak_window = 0
    bound = 0
    for r in range(2000):
        if r % 7 == 3 and stage.pool.workers:
            stage.kill_worker(r % 3)
        graph.step(now)
        now += 1.0
        uncommitted = sum(
            p.end_offset() - stage._watermark.get(p.index, 0)
            for p in stage.in_topic.partitions
        )
        peak_window = max(peak_window, stage.dedup_size())
        # window <= a small multiple of the uncommitted suffix
        bound = max(bound, 4 * uncommitted + 8)
        assert stage.dedup_size() <= 4 * uncommitted + 8, (
            r, stage.dedup_size(), uncommitted
        )
        if graph.pending() == 0 and r > 4:
            break
    assert sorted(stage.outputs()) == sorted(range(600))
    # and after the run everything committed: windows are ~empty
    assert stage.dedup_size() <= 8
    assert peak_window < 600, "window tracked history, not the suffix"


@settings(deadline=None, max_examples=20)
@given(st.lists(st.integers(min_value=0, max_value=5), min_size=1,
                max_size=40))
def test_dedup_window_eviction_property(offsets_per_commit):
    """Property: feeding N keys and committing in arbitrary chunks keeps
    the window at O(suffix) — after every commit the window holds
    exactly the keys at/above the watermark."""
    d = DedupWindow()
    watermark = 0
    total = 0
    for chunk in offsets_per_commit:
        for _ in range(chunk):
            d.seen((0, total))
            total += 1
        # commit everything but an arbitrary (bounded) suffix; the
        # watermark only ever moves forward
        watermark = min(max(watermark, total - (chunk % 3)), total)
        d.evict_below({0: watermark})
        assert len(d) == total - watermark


# --- torn trailing JSONL line (satellite) -------------------------------------


def test_torn_trailing_spill_line_truncated_and_recovered(tmp_path):
    """A process killed mid-append leaves a half-written JSONL tail;
    reopen must truncate to the last complete record and keep going —
    appends continue onto the clean prefix."""
    d = str(tmp_path / "log")
    log = MessageLog(spill_dir=d)
    log.create_topic("t", 1)
    for i in range(5):
        log.publish("t", payload={"i": i})
    log.close()
    path = os.path.join(d, "t-p0.jsonl")
    size = os.path.getsize(path)
    with open(path, "a", encoding="utf-8") as fh:
        fh.write('{"payload": {"i": 99}, "key"')  # killed mid-append

    re = MessageLog.reopen(d)
    part = re.get("t").partitions[0]
    assert part.end_offset() == 5
    assert [m.payload["i"] for m in part.read(0, 10)] == list(range(5))
    assert os.path.getsize(path) == size  # file physically truncated
    re.publish("t", payload={"i": 5})
    re.close()
    re2 = MessageLog.reopen(d)
    assert [m.payload["i"] for m in re2.get("t").partitions[0].read(0, 10)] \
        == [0, 1, 2, 3, 4, 5]


def test_torn_line_without_newline_terminator(tmp_path):
    """Complete JSON but no trailing newline is also a torn append (the
    terminator write never landed): drop it, or the next append would
    concatenate onto it."""
    d = str(tmp_path / "log")
    log = MessageLog(spill_dir=d)
    log.create_topic("t", 1)
    for i in range(3):
        log.publish("t", payload=i)
    log.close()
    path = os.path.join(d, "t-p0.jsonl")
    with open(path, "a", encoding="utf-8") as fh:
        fh.write('{"payload": 99, "key": null, "created_at": 0.0}')  # no \n

    re = MessageLog.reopen(d)
    assert re.get("t").partitions[0].end_offset() == 3
    re.publish("t", payload=3)
    re.close()
    assert [m.payload for m in
            MessageLog.reopen(d).get("t").partitions[0].read(0, 10)] \
        == [0, 1, 2, 3]


def test_mid_file_corruption_refuses_to_drop_data(tmp_path):
    d = str(tmp_path / "log")
    log = MessageLog(spill_dir=d)
    log.create_topic("t", 1)
    for i in range(3):
        log.publish("t", payload=i)
    log.close()
    path = os.path.join(d, "t-p0.jsonl")
    lines = open(path, "r", encoding="utf-8").read().splitlines(True)
    lines[1] = '{"broken\n'
    with open(path, "w", encoding="utf-8") as fh:
        fh.writelines(lines)
    with pytest.raises(ValueError, match="mid-file"):
        MessageLog.reopen(d)


# --- producer-stage rejected demand (satellite) -------------------------------


def test_producer_group_reports_rejected_demand():
    from repro.core.messages import Message
    from repro.core.virtual_messaging import VirtualProducerGroup
    from repro.data.topics import Topic

    out = Topic("out", 1)
    pg = VirtualProducerGroup(out, initial_size=2, producer_capacity=2)
    for i in range(8):  # 4 fit (2 producers x cap 2), 4 are overflow
        pg.submit(Message(topic="out", payload=i))
    assert pg.pending() == 8  # overflow-safe: nothing dropped
    assert pg.rejected == 4
    assert pg.take_rejected() == 4
    assert pg.take_rejected() == 0  # drained
    assert pg.pool.counter("vp.rejected") == 4
    while pg.step_all() > 0:
        pass
    assert out.total_messages() == 8


def test_producer_resize_reports_survivor_saturation():
    from repro.core.messages import Message
    from repro.core.virtual_messaging import VirtualProducerGroup
    from repro.data.topics import Topic

    out = Topic("out", 1)
    pg = VirtualProducerGroup(out, initial_size=4, producer_capacity=2)
    for i in range(8):  # exactly fills 4 producers x cap 2: no rejects
        pg.submit(Message(topic="out", payload=i))
    assert pg.take_rejected() == 0
    pg.resize(1)  # survivors now hold 8 > capacity 2
    assert pg.take_rejected() >= 6
    while pg.step_all() > 0:
        pass
    assert out.total_messages() == 8


def test_source_saturation_feeds_stage_autoscaler():
    """Stage wiring: a saturated source producer group's rejected demand
    reaches the stage's autoscaler via note_rejected (the serving-ingress
    pattern), so the stage scales out for demand it cannot yet see."""
    from repro.core.messages import Message
    from repro.core.virtual_messaging import VirtualProducerGroup

    log = MessageLog()
    log.create_topic("in", 1)
    log.create_topic("out", 1)
    pg = VirtualProducerGroup(log.get("in"), initial_size=1,
                              producer_capacity=1)
    graph = StageGraph(log)
    stage = graph.add(Stage(
        "s", log, "in", "out", process=lambda m: [m.payload],
        source=pg, initial_tasks=1,
        autoscaler=AutoscalerConfig(high_watermark=2.0, low_watermark=0.0,
                                    min_workers=1, max_workers=8,
                                    cooldown=0.0),
    ))
    for i in range(24):
        pg.submit(Message(topic="in", payload=i))
    assert pg.rejected > 0
    graph.step(0.0)  # rejected demand reaches the stage before the data
    assert stage.pool.target_units() > 1
    for r in range(1, 200):
        pg.step_all()
        graph.step(float(r))
        if graph.pending() == 0 and pg.pending() == 0:
            break
    assert sorted(stage.outputs()) == sorted(range(24))


# --- write-behind journal durability (ISSUE 8) --------------------------------


def test_durable_offsets_gate_on_write_behind_journal(tmp_path):
    """With write-behind journaling the commit *decision* stays on the
    step, but ``durable_offsets()`` — the view a commit gate should use
    — advances only as journal lines actually land on disk: it lags
    ``committed_offsets()`` while the worker is stalled and converges
    after a flush."""
    from repro.checkpoint.store import WriteBehind

    log = MessageLog()
    fill(log, "in", 24)
    log.create_topic("out", 3)
    jd = str(tmp_path / "j")
    os.makedirs(jd, exist_ok=True)
    wb = WriteBehind("test-journal")
    wb.pause()
    stage = Stage("s", log, "in", "out", process=lambda m: [m.payload],
                  initial_tasks=2, heartbeat_timeout=2.0, batch_n=8,
                  elastic=False,
                  journal_factory=lambda p: EventJournal(
                      os.path.join(jd, f"p{p}.journal")),
                  journal_write_behind=wb)
    for t in range(40):
        stage.step(float(t))
    committed = stage.committed_offsets()
    assert sum(committed.values()) == 24, committed
    # in-memory watermark moved; nothing is durable yet
    assert sum(stage.durable_offsets().values()) == 0
    wb.resume()
    wb.flush()
    assert stage.durable_offsets() == committed
    # the journal files really carry the lines the tickets gated on
    for p in committed:
        assert os.path.getsize(os.path.join(jd, f"p{p}.journal")) > 0
    stage.close()


def test_durable_offsets_equals_committed_without_write_behind():
    log = MessageLog()
    fill(log, "in", 12)
    log.create_topic("out", 3)
    stage = Stage("s", log, "in", "out", process=lambda m: [m.payload],
                  initial_tasks=2, heartbeat_timeout=2.0, batch_n=8,
                  elastic=False)
    for t in range(20):
        stage.step(float(t))
    assert stage.durable_offsets() == stage.committed_offsets()
    assert sum(stage.committed_offsets().values()) == 12
    stage.close()

"""Property tests for the CRDT laws (paper §3.2.2 state management).

State-based CRDTs must form a join-semilattice: merge commutative,
associative, idempotent; local updates monotone. Convergence follows.
"""

from _hypothesis_support import given, settings, st  # noqa: F401

from repro.core.crdt import (
    GCounter,
    GSet,
    LWWRegister,
    ORSet,
    PNCounter,
    VClock,
    merge_all,
)

# --- strategies -------------------------------------------------------------

replica_ids = st.sampled_from(["r0", "r1", "r2", "r3"])


@st.composite
def gcounters(draw):
    n = draw(st.integers(0, 4))
    counts = {f"r{i}": draw(st.integers(0, 100)) for i in range(n)}
    return GCounter(draw(replica_ids), counts)


@st.composite
def pncounters(draw):
    g1 = draw(gcounters())
    g2 = draw(gcounters())
    out = PNCounter(g1.replica_id)
    out.pos, out.neg = g1, g2.copy_as(g1.replica_id)
    return out


@st.composite
def lww(draw):
    return LWWRegister(
        value=draw(st.integers()),
        timestamp=draw(st.floats(0, 1e6, allow_nan=False)),
        tiebreak=draw(st.text(max_size=3)),
    )


@st.composite
def gsets(draw):
    return GSet(draw(st.frozensets(st.integers(0, 50), max_size=8)))


@st.composite
def orsets(draw):
    s = ORSet()
    for _ in range(draw(st.integers(0, 6))):
        item = draw(st.integers(0, 10))
        if draw(st.booleans()):
            s = s.add(item)
        else:
            s = s.remove(item)
    return s


@st.composite
def vclocks(draw):
    n = draw(st.integers(0, 4))
    return VClock({f"r{i}": draw(st.integers(0, 20)) for i in range(n)})


STRATS = {
    "gcounter": gcounters(),
    "pncounter": pncounters(),
    "lww": lww(),
    "gset": gsets(),
    "orset": orsets(),
    "vclock": vclocks(),
}


def _value(x):
    """Observable value used for equality in the semilattice checks."""
    if isinstance(x, (GCounter, PNCounter)):
        return x.value()
    if isinstance(x, LWWRegister):
        return (x.value, x.timestamp, x.tiebreak)
    if isinstance(x, GSet):
        return x.items
    if isinstance(x, ORSet):
        return x.elements()
    if isinstance(x, VClock):
        return {k: v for k, v in x.clock.items() if v}
    raise TypeError(x)


# --- the CRDT laws, for every type -------------------------------------------


@given(a=gcounters(), b=gcounters())
def test_gcounter_commutative(a, b):
    assert _value(a.merge(b)) == _value(b.merge(a))


@given(a=gcounters(), b=gcounters(), c=gcounters())
def test_gcounter_associative(a, b, c):
    assert _value(a.merge(b).merge(c)) == _value(a.merge(b.merge(c)))


@given(a=gcounters())
def test_gcounter_idempotent(a):
    assert _value(a.merge(a)) == _value(a)


@given(a=pncounters(), b=pncounters())
def test_pncounter_commutative(a, b):
    assert _value(a.merge(b)) == _value(b.merge(a))


@given(a=pncounters(), b=pncounters(), c=pncounters())
def test_pncounter_associative(a, b, c):
    assert _value(a.merge(b).merge(c)) == _value(a.merge(b.merge(c)))


@given(a=pncounters())
def test_pncounter_idempotent(a):
    assert _value(a.merge(a)) == _value(a)


@given(a=lww(), b=lww())
def test_lww_commutative(a, b):
    assert _value(a.merge(b)) == _value(b.merge(a))


@given(a=lww(), b=lww(), c=lww())
def test_lww_associative(a, b, c):
    assert _value(a.merge(b).merge(c)) == _value(a.merge(b.merge(c)))


@given(a=lww())
def test_lww_idempotent(a):
    assert _value(a.merge(a)) == _value(a)


@given(a=gsets(), b=gsets())
def test_gset_commutative(a, b):
    assert _value(a.merge(b)) == _value(b.merge(a))


@given(a=gsets(), b=gsets(), c=gsets())
def test_gset_associative(a, b, c):
    assert _value(a.merge(b).merge(c)) == _value(a.merge(b.merge(c)))


@given(a=gsets())
def test_gset_idempotent(a):
    assert _value(a.merge(a)) == _value(a)


@given(a=orsets(), b=orsets())
def test_orset_commutative(a, b):
    assert _value(a.merge(b)) == _value(b.merge(a))


@given(a=orsets(), b=orsets(), c=orsets())
def test_orset_associative(a, b, c):
    assert _value(a.merge(b).merge(c)) == _value(a.merge(b.merge(c)))


@given(a=orsets())
def test_orset_idempotent(a):
    assert _value(a.merge(a)) == _value(a)


@given(a=vclocks(), b=vclocks())
def test_vclock_commutative(a, b):
    assert _value(a.merge(b)) == _value(b.merge(a))


@given(a=vclocks(), b=vclocks(), c=vclocks())
def test_vclock_associative(a, b, c):
    assert _value(a.merge(b).merge(c)) == _value(a.merge(b.merge(c)))


# --- smoke (no hypothesis needed) ---------------------------------------------


def test_semilattice_laws_smoke():
    """Deterministic spot-check of the merge laws; runs even when the
    property suite above is skipped for lack of hypothesis."""
    a, b, c = GCounter("r0"), GCounter("r1"), GCounter("r2")
    a.increment(3)
    b.increment(5)
    c.increment(7)
    assert a.merge(b).value() == b.merge(a).value() == 8
    assert a.merge(b).merge(c).value() == a.merge(b.merge(c)).value() == 15
    assert a.merge(a).value() == a.value() == 3
    assert merge_all([a, b, c]).value() == 15


# --- behavioural properties ---------------------------------------------------


@given(st.lists(st.integers(0, 1000), min_size=1, max_size=20))
def test_gcounter_convergence(increments):
    """Replicas incremented independently converge to the global sum."""
    replicas = [GCounter(f"r{i}") for i in range(4)]
    for k, amount in enumerate(increments):
        replicas[k % 4].increment(amount)
    merged = merge_all(replicas)
    assert merged.value() == sum(increments)


@given(st.lists(st.integers(-100, 100), min_size=1, max_size=20))
def test_pncounter_convergence(deltas):
    replicas = [PNCounter(f"r{i}") for i in range(3)]
    for k, d in enumerate(deltas):
        replicas[k % 3].increment(d)
    merged = merge_all(replicas)
    assert merged.value() == sum(deltas)


def test_orset_add_wins():
    """A concurrent re-add survives a remove of the earlier observation."""
    a = ORSet().add("x")
    b = a  # replicate
    a2 = a.remove("x")           # replica A removes the observed tag
    b2 = b.add("x")              # replica B concurrently re-adds
    merged = a2.merge(b2)
    assert "x" in merged


def test_vclock_causality():
    a = VClock().tick("r0")
    b = a.tick("r1")
    assert a.happens_before(b)
    assert not b.happens_before(a)
    c = a.tick("r2")
    assert b.concurrent_with(c)


def test_gcounter_rejects_negative():
    import pytest

    g = GCounter("r0")
    with pytest.raises(ValueError):
        g.increment(-1)

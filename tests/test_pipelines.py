"""Liquid vs Reactive live pipelines + the paper's structural claims."""

from repro.core.liquid import LiquidJob
from repro.core.messages import Message
from repro.core.reactive import ReactiveJob
from repro.data.topics import MessageLog


def fill(log: MessageLog, topic: str, n: int, partitions: int = 3) -> None:
    if not log.exists(topic):
        log.create_topic(topic, partitions)
    for i in range(n):
        log.publish(topic, payload=i)


def double(msg: Message):
    return [msg.payload * 2]


def test_liquid_active_task_limit():
    """Six tasks, three partitions: only three make progress (Fig. 2)."""
    log = MessageLog()
    fill(log, "in", 60, partitions=3)
    job = LiquidJob("j", log, "in", double, num_tasks=6)
    assert job.active_tasks == 3
    job.run_to_completion()
    worked = [t.stats.processed for t in job.tasks]
    assert sum(1 for w in worked if w > 0) == 3
    assert sum(worked) == 60


def test_liquid_processes_everything_and_publishes():
    log = MessageLog()
    fill(log, "in", 30, partitions=3)
    log.create_topic("out", 3)
    job = LiquidJob("j", log, "in", double, out_topic="out", num_tasks=3)
    job.run_to_completion()
    assert job.total_processed() == 30
    assert log.get("out").total_messages() == 30


def test_reactive_all_tasks_work_past_partition_limit():
    """Eight tasks on a three-partition topic all receive work."""
    log = MessageLog()
    fill(log, "in", 160, partitions=3)
    job = ReactiveJob("j", log, "in", double, initial_tasks=8, elastic=False)
    job.run_to_completion()
    assert job.total_processed() == 160
    worked = [t.stats.processed for t in job.tasks if t.stats.processed > 0]
    assert len(worked) >= 6  # strictly more than the partition count


def test_reactive_publishes_results():
    log = MessageLog()
    fill(log, "in", 40, partitions=2)
    log.create_topic("out", 2)
    job = ReactiveJob("j", log, "in", double, out_topic="out", initial_tasks=4)
    job.run_to_completion()
    assert log.get("out").total_messages() == 40
    outs = set()
    for p in log.get("out").partitions:
        outs.update(m.payload for m in p.read(0, 1000))
    assert outs == {2 * i for i in range(40)}


def test_reactive_task_crash_heals_and_loses_nothing():
    """Kill a task mid-stream: supervisor restarts it, mailbox moves over,
    dedup prevents double effects."""
    log = MessageLog()
    fill(log, "in", 120, partitions=3)
    seen = []
    job = ReactiveJob("j", log, "in", lambda m: (seen.append(m.payload), [])[1],
                      initial_tasks=4, heartbeat_timeout=2.0)
    job.step(now=0.0)
    victim = job.tasks[0]
    victim.alive = False  # crash: stops processing + heartbeating
    t = 0.0
    for r in range(1, 400):
        t += 1.0
        job.step(now=t)
        if job.backlog() == 0:
            break
    assert any(e[1] == "restarted" for e in job.supervisor.events)
    assert job.backlog() == 0
    assert sorted(seen) == sorted(range(120))  # nothing lost, nothing doubled


def test_reactive_consumer_crash_resumes_from_offset():
    log = MessageLog()
    fill(log, "in", 90, partitions=3)
    got = []
    job = ReactiveJob("j", log, "in", lambda m: (got.append(m.payload), [])[1],
                      initial_tasks=3, heartbeat_timeout=2.0)
    job.step(now=0.0)
    job.consumer_group.consumers[0].alive = False  # crash a virtual consumer
    t = 0.0
    for _ in range(400):
        t += 1.0
        job.step(now=t)
        if job.backlog() == 0:
            break
    assert job.backlog() == 0
    assert sorted(got) == sorted(range(90))


def test_reactive_elastic_scale_out_and_in():
    log = MessageLog()
    fill(log, "in", 400, partitions=2)
    from repro.core.elastic import AutoscalerConfig

    job = ReactiveJob(
        "j", log, "in", double, initial_tasks=2,
        autoscaler=AutoscalerConfig(
            high_watermark=8, low_watermark=1, min_workers=2,
            max_workers=16, cooldown=0.0,
        ),
        batch_n=50,
    )
    t = 0.0
    peak = 2
    for _ in range(200):
        t += 1.0
        job.step(now=t, task_budget=2)  # slow tasks -> backlog builds
        peak = max(peak, len(job.tasks))
        if job.backlog() == 0:
            break
    assert peak > 2  # scaled out under backlog
    for _ in range(10):
        t += 1.0
        job.step(now=t)
    assert len(job.tasks) <= peak  # scaled (or scaling) back in when idle
    assert job.total_processed() == 400

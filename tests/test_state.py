"""Event-sourcing properties: replay determinism, snapshot equivalence,
idempotent redelivery, file-backed crash recovery."""

from _hypothesis_support import given, st

from repro.core.state import Event, EventJournal, EventSourcedState, dict_reducer


@st.composite
def event_batches(draw):
    n = draw(st.integers(1, 30))
    out = []
    for _ in range(n):
        kind = draw(st.sampled_from(["set", "incr", "del"]))
        key = draw(st.sampled_from(["a", "b", "c"]))
        if kind == "set":
            data = {"key": key, "value": draw(st.integers(-50, 50))}
        elif kind == "incr":
            data = {"key": key, "amount": draw(st.integers(-5, 5))}
        else:
            data = {"key": key}
        out.append((kind, data))
    return out


@given(event_batches())
def test_replay_determinism(batch):
    s1 = EventSourcedState({}, dict_reducer)
    s2 = EventSourcedState({}, dict_reducer)
    for kind, data in batch:
        s1.record(kind, data)
        s2.record(kind, data)
    assert s1.state == s2.state
    assert s1.replay() == s2.replay()


@given(event_batches(), st.integers(0, 29))
def test_snapshot_equivalence(batch, snap_at):
    """snapshot at k + replay suffix == full replay."""
    full = EventSourcedState({}, dict_reducer)
    snapped = EventSourcedState({}, dict_reducer)
    for i, (kind, data) in enumerate(batch):
        full.record(kind, data)
        snapped.record(kind, data)
        if i == min(snap_at, len(batch) - 1):
            snapped.snapshot()
    assert snapped.replay() == full.state


@given(event_batches())
def test_compaction_preserves_state(batch):
    s = EventSourcedState({}, dict_reducer)
    for kind, data in batch:
        s.record(kind, data)
    before = dict(s.state)
    dropped = s.compact()
    assert dropped == len(batch)
    assert s.replay() == before


def test_replay_determinism_smoke():
    """Deterministic replay check; runs even without hypothesis."""
    batch = [
        ("set", {"key": "a", "value": 1}),
        ("incr", {"key": "a", "amount": 2}),
        ("del", {"key": "b"}),
    ]
    s1 = EventSourcedState({}, dict_reducer)
    s2 = EventSourcedState({}, dict_reducer)
    for kind, data in batch:
        s1.record(kind, data)
        s2.record(kind, data)
    assert s1.state == s2.state == {"a": 3}
    assert s1.replay() == s2.replay()


def test_idempotent_redelivery():
    s = EventSourcedState({}, dict_reducer)
    ev = s.record("incr", {"key": "a", "amount": 5})
    assert s.state == {"a": 5}
    s._apply(ev)  # redeliver the same event
    s._apply(ev)
    assert s.state == {"a": 5}


def test_file_backed_crash_recovery(tmp_path):
    """A new process (new journal object on the same file) recovers state."""
    path = str(tmp_path / "journal.jsonl")
    j1 = EventJournal(path)
    s1 = EventSourcedState({}, dict_reducer, j1)
    s1.record("set", {"key": "step", "value": 41})
    s1.record("incr", {"key": "step", "amount": 1})
    j1.close()
    # "crash" — rebuild everything from the file.
    j2 = EventJournal(path)
    s2 = EventSourcedState({}, dict_reducer, j2)
    assert s2.state == {"step": 42}
    assert s2.applied_seq == 1
    j2.close()


def test_file_backed_truncation(tmp_path):
    path = str(tmp_path / "journal.jsonl")
    j = EventJournal(path)
    s = EventSourcedState({}, dict_reducer, j)
    for i in range(10):
        s.record("set", {"key": "k", "value": i})
    s.compact()
    s.record("incr", {"key": "k", "amount": 1})
    j.close()
    j2 = EventJournal(path)
    assert len(j2.all_events()) == 1  # only the post-compaction suffix
    j2.close()


def test_event_json_roundtrip():
    ev = Event(seq=3, kind="set", data={"key": "x", "value": [1, 2]}, timestamp=1.5)
    assert Event.from_json(ev.to_json()) == ev

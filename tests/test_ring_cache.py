"""Ring-buffer KV caches for sliding-window layers: decode results must
match the full-length linear cache exactly (the window mask sees the
same live positions either way)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_arch
from repro.models.zoo import build_model

pytestmark = pytest.mark.slow  # heavy sweep/compile module: excluded from tier-1


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "gemma3-4b"])
def test_ring_cache_decode_matches_linear(arch):
    cfg = get_arch(arch, smoke=True)  # windows 16 (mixtral), 8 (gemma3)
    model = build_model(cfg, compute_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    B, PRE, STEPS, TOTAL = 2, 40, 6, 64
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, TOTAL), 0,
                              cfg.vocab_size)

    def run(ring):
        cache = model.init_cache(B, TOTAL, ring=ring)
        _, cache = model.prefill(params, {"tokens": toks[:, :PRE]}, cache)
        outs = []
        for i in range(STEPS):
            pos = jnp.full((B,), PRE + i, dtype=jnp.int32)
            logits, cache = model.decode_step(
                params, toks[:, PRE + i : PRE + i + 1], cache, pos
            )
            outs.append(np.asarray(logits[:, 0]))
        return np.stack(outs)

    linear = run(ring=False)
    ring = run(ring=True)
    np.testing.assert_allclose(ring, linear, rtol=2e-4, atol=2e-4)


def test_ring_cache_is_actually_small():
    cfg = get_arch("mixtral-8x7b", smoke=True)  # window 16
    model = build_model(cfg, compute_dtype=jnp.float32)
    full = model.init_cache(2, 512, ring=False)
    ring = model.init_cache(2, 512, ring=True)

    def cache_bytes(c):
        return sum(
            np.prod(l.shape) * l.dtype.itemsize for l in jax.tree.leaves(c)
        )

    assert cache_bytes(ring) < cache_bytes(full) / 10  # W=16 vs 512


def test_ring_prefill_longer_than_window():
    """A prefill chunk longer than the ring must keep only the newest W
    positions and still decode correctly afterwards."""
    cfg = get_arch("mixtral-8x7b", smoke=True)
    model = build_model(cfg, compute_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    B, PRE, TOTAL = 2, 48, 64  # PRE (48) > window (16)
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, TOTAL), 0,
                              cfg.vocab_size)

    def decode_after_prefill(ring):
        cache = model.init_cache(B, TOTAL, ring=ring)
        _, cache = model.prefill(params, {"tokens": toks[:, :PRE]}, cache)
        pos = jnp.full((B,), PRE, dtype=jnp.int32)
        logits, _ = model.decode_step(params, toks[:, PRE:PRE + 1], cache, pos)
        return np.asarray(logits[:, 0])

    np.testing.assert_allclose(
        decode_after_prefill(True), decode_after_prefill(False),
        rtol=2e-4, atol=2e-4,
    )

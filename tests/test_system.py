"""End-to-end system tests: the whole stack wired together.

1. Train -> crash -> resume produces the same final state as an
   uninterrupted run (exact checkpoint/restart, in-process).
2. The process-level failure drill (subprocess, hard kill, supervisor
   relaunch) completes training.
3. The live threaded runtime heals a killed worker under real
   concurrency.
"""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_crash_resume_equals_uninterrupted_run(tmp_path):
    """Determinism across Let-It-Crash: snapshot at k, rebuild, continue —
    identical final params to never crashing."""
    from repro.checkpoint.store import CheckpointStore
    from repro.config import TrainingConfig, get_arch
    from repro.data.pipeline import PipelineConfig, TokenPipeline, build_token_log
    from repro.models.zoo import build_model
    from repro.training.train_step import init_train_state, make_train_step

    cfg = get_arch("llama3.2-1b", smoke=True)
    tcfg = TrainingConfig(learning_rate=1e-3, warmup_steps=0, schedule="constant")
    model = build_model(cfg, compute_dtype=jnp.float32)
    step_fn = jax.jit(make_train_step(model, tcfg))

    def make_pipe():
        return TokenPipeline(
            build_token_log(cfg.vocab_size, 256, doc_len=33, partitions=3),
            PipelineConfig(partitions=3, num_queues=4, batch_size=4, seq_len=16),
        )

    # --- uninterrupted run: 10 steps
    pipe = make_pipe()
    state = init_train_state(model, tcfg, jax.random.PRNGKey(0))
    for _ in range(10):
        state, _ = step_fn(state, {k: jnp.asarray(v) for k, v in
                                   pipe.next_batch().items()})
    golden = state

    # --- crashed run: 5 steps, snapshot, "crash", rebuild, 5 more
    store = CheckpointStore(str(tmp_path))
    pipe = make_pipe()
    state = init_train_state(model, tcfg, jax.random.PRNGKey(0))
    for _ in range(5):
        state, _ = step_fn(state, {k: jnp.asarray(v) for k, v in
                                   pipe.next_batch().items()})
    store.save(state, step=5, extra={"pipeline": pipe.state_dict()})
    del state, pipe  # the crash

    template = jax.eval_shape(
        lambda r: init_train_state(model, tcfg, r), jax.random.PRNGKey(0)
    )
    template = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), template)
    restored, meta, _ = store.restore_latest(template)
    pipe2 = make_pipe()
    pipe2.load_state_dict(meta["pipeline"])
    state = restored
    for _ in range(5):
        state, _ = step_fn(state, {k: jnp.asarray(v) for k, v in
                                   pipe2.next_batch().items()})

    for a, b in zip(jax.tree.leaves(golden.params), jax.tree.leaves(state.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_process_level_failure_drill(tmp_path):
    """Hard-kill a real training process mid-run; the supervisor restarts
    it with --resume and training completes."""
    from repro.launch.cluster import ProcessSupervisor, WorkerSpec

    spec = WorkerSpec(
        name="w0",
        heartbeat_file=str(tmp_path / "hb"),
        args=[
            "--arch", "llama3.2-1b", "--steps", "12",
            "--batch-size", "2", "--seq-len", "16",
            "--checkpoint-dir", str(tmp_path / "ckpt"),
            # crash on a checkpoint boundary so the resumed run continues
            # past it (crashing between checkpoints would re-execute the
            # crash step — which is also a useful drill, but a different one)
            "--checkpoint-every", "4", "--crash-at-step", "8",
            "--num-docs", "256", "--log-every", "4",
        ],
    )
    sup = ProcessSupervisor(spec, heartbeat_timeout=120.0, max_restarts=2)
    code = sup.run(total_timeout=420.0)
    assert code == 0
    assert sup.restarts == 1
    kinds = [e.kind for e in sup.events]
    assert kinds.count("started") == 2
    assert "finished" in kinds


def test_threaded_runtime_heals_killed_trainer():
    """The generalized runtime (ISSUE 3 satellite) drives an ElasticPool-
    backed *training* job under wall-clock supervision: a silenced DP
    worker is healed and training completes with exact consumption."""
    from repro.config import TrainingConfig, get_arch
    from repro.core.runtime import ThreadedRuntime
    from repro.data.pipeline import build_token_log
    from repro.models.zoo import build_model
    from repro.training.job import TrainingJob

    cfg = get_arch("llama3.2-1b", smoke=True)
    tcfg = TrainingConfig(learning_rate=1e-3, warmup_steps=0,
                          schedule="constant")
    model = build_model(cfg, compute_dtype=jnp.float32)
    log = build_token_log(cfg.vocab_size, 48, doc_len=17, partitions=3)
    job = TrainingJob(model, cfg, tcfg, log, batch_size=4, seq_len=16,
                      dp=2, max_dp=2, heartbeat_timeout=0.25,
                      shard_budget=1)
    rt = ThreadedRuntime(job, tick=0.005)
    rt.start()
    deadline = time.monotonic() + 60.0
    while job.applied_step() < 2 and time.monotonic() < deadline:
        time.sleep(0.01)  # let it get in flight (incl. the jit compile)
    killed = rt.kill_worker(0)
    assert killed.startswith("train:dp")
    processed = rt.drain(timeout=60.0)
    rt.stop()
    assert processed == 12  # 48 docs / batch 4: the whole stream
    assert job.backlog() == 0
    assert any(e[1] == "restarted" for e in job.supervisor.events)
    assert rt.stats.restarts >= 1
    assert sum(job.committed_offsets().values()) == 48


def test_threaded_runtime_heals_killed_worker():
    from repro.core.reactive import ReactiveJob
    from repro.core.runtime import ThreadedRuntime
    from repro.data.topics import MessageLog

    log = MessageLog()
    log.create_topic("in", 3)
    for i in range(300):
        log.publish("in", payload=i)
    seen = []

    def slow_process(m):
        time.sleep(0.002)  # keep the backlog alive past the kill
        seen.append(m.payload)
        return []

    job = ReactiveJob("j", log, "in", slow_process,
                      initial_tasks=4, heartbeat_timeout=0.2, elastic=False)
    rt = ThreadedRuntime(job, tick=0.001)
    rt.start()
    time.sleep(0.1)
    killed_task = rt.kill_task(0)
    killed_vc = rt.kill_consumer(0)
    assert job.backlog() > 0, "workload should still be in flight"
    processed = rt.drain(timeout=60.0)
    rt.stop()
    assert processed == 300
    assert sorted(seen) == sorted(range(300))
    assert any(e[1] == "restarted" for e in job.supervisor.events)

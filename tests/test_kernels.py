"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracles,
swept over shapes and dtypes, plus hypothesis property tests on the
kernels' invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_support import given, settings, st

from repro.kernels.decode_attention.ops import (
    decode_attention,
    paged_decode_attention,
    paged_kv_append,
)
from repro.kernels.decode_attention.ref import (
    decode_attention_ref,
    gather_pages,
    paged_decode_attention_ref,
    paged_kv_append_ref,
)
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.moe_gating.ops import moe_gating
from repro.kernels.moe_gating.ref import moe_gating_ref
from repro.kernels.ssd_scan.ops import ssd_chunked
from repro.kernels.ssd_scan.ref import ssd_chunked_ref, ssd_sequential_ref
from repro.kernels.tcmm_assign.ops import tcmm_assign
from repro.kernels.tcmm_assign.ref import tcmm_assign_ref

K = jax.random.PRNGKey

TOLS = {jnp.float32: dict(rtol=1e-5, atol=1e-5), jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,t,h,hkv,d,causal,window",
    [
        (1, 128, 4, 4, 64, True, 0),     # MHA causal
        (2, 256, 8, 2, 64, True, 0),     # GQA
        (1, 256, 4, 1, 128, True, 64),   # sliding window, MQA
        (2, 128, 4, 2, 32, False, 0),    # bidirectional (encoder)
        (1, 512, 2, 2, 64, True, 128),   # longer seq + window
    ],
)
def test_flash_attention_matches_ref(b, t, h, hkv, d, causal, window, dtype):
    ks = jax.random.split(K(0), 3)
    q = jax.random.normal(ks[0], (b, t, h, d), dtype=dtype)
    k = jax.random.normal(ks[1], (b, t, hkv, d), dtype=dtype)
    v = jax.random.normal(ks[2], (b, t, hkv, d), dtype=dtype)
    out = flash_attention(
        q, k, v, causal=causal, window=window, block_q=64, block_k=64,
        interpret=True,
    )
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, dtype=np.float32), np.asarray(ref, dtype=np.float32),
        **TOLS[dtype],
    )


def test_flash_attention_q_offset_decode_chunk():
    """Chunked prefill: q block at offset into a longer KV context."""
    ks = jax.random.split(K(1), 3)
    b, t, s, h, d = 1, 64, 256, 2, 64
    q = jax.random.normal(ks[0], (b, t, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    out = flash_attention(
        q, k, v, causal=True, q_offset=192, block_q=64, block_k=64,
        interpret=True,
    )
    ref = attention_ref(q, k, v, causal=True, q_offset=192)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    t=st.sampled_from([128, 256]),
    h=st.sampled_from([2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_attention_rows_sum_to_one_property(t, h, seed):
    """Softmax property: with v = identity-ish all-ones, output rows == 1."""
    ks = jax.random.split(K(seed), 2)
    q = jax.random.normal(ks[0], (1, t, h, 64))
    k = jax.random.normal(ks[1], (1, t, h, 64))
    v = jnp.ones((1, t, h, 64))
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(out), 1.0, rtol=1e-4, atol=1e-4)


def test_flash_attention_rows_sum_to_one_smoke():
    """Single-seed version of the softmax property; runs without hypothesis."""
    ks = jax.random.split(K(11), 2)
    t, h = 128, 2
    q = jax.random.normal(ks[0], (1, t, h, 64))
    k = jax.random.normal(ks[1], (1, t, h, 64))
    v = jnp.ones((1, t, h, 64))
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(out), 1.0, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,h,hkv,d,window",
    [
        (2, 256, 8, 2, 64, 0),
        (1, 512, 4, 1, 128, 0),
        (4, 256, 8, 8, 64, 0),
        (2, 512, 8, 2, 64, 128),  # sliding-window decode
    ],
)
def test_decode_attention_matches_ref(b, s, h, hkv, d, window, dtype):
    ks = jax.random.split(K(2), 4)
    q = jax.random.normal(ks[0], (b, h, d), dtype=dtype)
    kc = jax.random.normal(ks[1], (b, s, hkv, d), dtype=dtype)
    vc = jax.random.normal(ks[2], (b, s, hkv, d), dtype=dtype)
    kv_len = jax.random.randint(ks[3], (b,), 1, s + 1)
    out = decode_attention(q, kc, vc, kv_len, window=window, block_k=128,
                           interpret=True)
    ref = decode_attention_ref(q, kc, vc, kv_len, window=window)
    np.testing.assert_allclose(
        np.asarray(out, dtype=np.float32), np.asarray(ref, dtype=np.float32),
        **TOLS[dtype],
    )


def test_decode_attention_matches_flash_with_full_prefix():
    """decode(q over full cache) == last row of flash over the sequence."""
    ks = jax.random.split(K(3), 3)
    b, s, h, d = 2, 256, 4, 64
    q_full = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    flash = flash_attention(q_full, k, v, causal=True, block_q=64,
                            block_k=64, interpret=True)
    dec = decode_attention(
        q_full[:, -1], k, v, jnp.full((b,), s, dtype=jnp.int32),
        block_k=128, interpret=True,
    )
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(flash[:, -1]), rtol=1e-5, atol=1e-5
    )


def test_decode_attention_kv_len_zero_emits_zero():
    """A fresh slot (kv_len == 0) attends to nothing: the defined output
    is exactly zero — on the kernel AND the reference (a bare softmax
    over an all-masked row would emit a uniform garbage mixture)."""
    ks = jax.random.split(K(20), 3)
    b, s, h, d = 3, 256, 4, 64
    q = jax.random.normal(ks[0], (b, h, d))
    kc = jax.random.normal(ks[1], (b, s, h, d))
    vc = jax.random.normal(ks[2], (b, s, h, d))
    kv_len = jnp.asarray([0, 17, 0], dtype=jnp.int32)
    out = np.asarray(decode_attention(q, kc, vc, kv_len, block_k=128,
                                      interpret=True))
    ref = np.asarray(decode_attention_ref(q, kc, vc, kv_len))
    np.testing.assert_array_equal(out[0], 0.0)
    np.testing.assert_array_equal(out[2], 0.0)
    np.testing.assert_array_equal(ref[0], 0.0)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(out[1], ref[1], rtol=1e-5, atol=1e-5)
    assert np.abs(out[1]).max() > 0  # the live row is untouched by the fix


def test_decode_attention_kv_len_full_cache():
    """kv_len == S on every row (a slot that spent its whole budget):
    no off-by-one at the cache's end."""
    ks = jax.random.split(K(21), 3)
    b, s, h, d = 2, 256, 4, 64
    q = jax.random.normal(ks[0], (b, h, d))
    kc = jax.random.normal(ks[1], (b, s, h, d))
    vc = jax.random.normal(ks[2], (b, s, h, d))
    kv_len = jnp.full((b,), s, dtype=jnp.int32)
    out = decode_attention(q, kc, vc, kv_len, block_k=128, interpret=True)
    ref = decode_attention_ref(q, kc, vc, kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_decode_attention_wrapper_validation():
    """The wrapper rejects (eagerly, before tracing) the inputs the
    kernel would otherwise mishandle silently."""
    ks = jax.random.split(K(22), 3)
    b, s, h, d = 2, 128, 2, 64
    q = jax.random.normal(ks[0], (b, h, d))
    kc = jax.random.normal(ks[1], (b, s, h, d))
    vc = jax.random.normal(ks[2], (b, s, h, d))
    with pytest.raises(TypeError, match="integer-typed"):
        decode_attention(q, kc, vc, jnp.asarray([4.0, 8.0]), interpret=True)
    with pytest.raises(ValueError, match="exceeds the cache"):
        decode_attention(q, kc, vc, jnp.asarray([4, s + 1]), interpret=True)
    with pytest.raises(ValueError, match="negative"):
        decode_attention(q, kc, vc, jnp.asarray([-1, 4]), interpret=True)
    with pytest.raises(ValueError, match="block_k"):
        decode_attention(q, kc, vc, jnp.asarray([4, 8]), block_k=0,
                         interpret=True)


# ---------------------------------------------------------------------------
# paged decode attention
# ---------------------------------------------------------------------------


def _random_paged_cache(seed, b, n_slot_pages, page, hkv, d, pool_pages):
    """Pool tensors + a page table of distinct ids >= 1 (page 0 is the
    reserved scratch page — real slots never map to it)."""
    ks = jax.random.split(K(seed), 3)
    k_pages = jax.random.normal(ks[0], (pool_pages, page, hkv, d))
    v_pages = jax.random.normal(ks[1], (pool_pages, page, hkv, d))
    perm = jax.random.permutation(ks[2], jnp.arange(1, pool_pages))
    table = perm[: b * n_slot_pages].reshape(b, n_slot_pages)
    return k_pages, v_pages, table.astype(jnp.int32)


@pytest.mark.parametrize(
    "kv_len,window",
    [
        ([32, 9, 0], 0),   # full budget / crossing page 1->2 / fresh slot
        ([32, 17, 8], 6),  # sliding window straddling the 16-boundary
    ],
)
def test_paged_decode_matches_dense_gather(kv_len, window):
    """Paged kernel == dense kernel == oracle over the gathered cache.
    The table is a random permutation, so a row's pages are scattered
    through the pool (the gather really is exercised)."""
    b, h, hkv, d, page, n = 3, 4, 2, 64, 8, 4  # n*page = 32 tokens/slot
    kp, vp, table = _random_paged_cache(23, b, n, page, hkv, d, 1 + b * n)
    q = jax.random.normal(K(24), (b, h, d))
    kv = jnp.asarray(kv_len, dtype=jnp.int32)
    out = paged_decode_attention(q, kp, vp, table, kv, window=window,
                                 interpret=True)
    k_dense, v_dense = gather_pages(kp, table), gather_pages(vp, table)
    dense = decode_attention(q, k_dense, v_dense, kv, window=window,
                             block_k=128, interpret=True)
    ref = paged_decode_attention_ref(q, kp, vp, table, kv, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_paged_vs_dense_decode_property(seed):
    """Property: for any page permutation, ragged kv_lens (0..full) and
    window, the paged kernel equals the dense kernel over the gather."""
    b, h, hkv, d, page, n = 4, 4, 2, 32, 8, 3
    kp, vp, table = _random_paged_cache(seed, b, n, page, hkv, d,
                                        1 + b * n + 2)
    rng = np.random.RandomState(seed % (2**31 - 1))
    kv = jnp.asarray(rng.randint(0, n * page + 1, size=b), dtype=jnp.int32)
    window = int(rng.choice([0, 5, page + 1]))
    q = jax.random.normal(K(seed % 997), (b, h, d))
    out = paged_decode_attention(q, kp, vp, table, kv, window=window,
                                 interpret=True)
    dense = decode_attention(q, gather_pages(kp, table),
                             gather_pages(vp, table), kv, window=window,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense),
                               rtol=1e-5, atol=1e-5)


def test_paged_kv_append_matches_ref_at_page_boundaries():
    """Append at a page's first row, last row, and mid-page; everything
    not written stays bitwise identical (in-place aliasing is exact)."""
    b, hkv, d, page, n = 3, 2, 64, 8, 3
    kp, vp, table = _random_paged_cache(25, b, n, page, hkv, d, 1 + b * n)
    ks = jax.random.split(K(26), 2)
    kn = jax.random.normal(ks[0], (b, hkv, d))
    vn = jax.random.normal(ks[1], (b, hkv, d))
    pos = jnp.asarray([0, 7, 8], dtype=jnp.int32)  # start / last-of-0 / first-of-1
    # ref first: the kernel donates (aliases) the pool buffers.
    rk, rv = paged_kv_append_ref(kn, vn, kp, vp, table, pos)
    k2, v2 = paged_kv_append(kn, vn, kp, vp, table, pos, interpret=True)
    np.testing.assert_array_equal(np.asarray(k2), np.asarray(rk))
    np.testing.assert_array_equal(np.asarray(v2), np.asarray(rv))
    k2 = np.asarray(k2)
    tab = np.asarray(table)
    for row in range(b):
        p = int(pos[row])
        np.testing.assert_array_equal(
            k2[tab[row, p // page], p % page], np.asarray(kn)[row]
        )


def test_paged_kv_append_traced_oob_pos_lands_in_own_last_page():
    """Regression: an idle batcher slot's cache pos keeps advancing past
    ``n_pages * page_size`` (empty slots still ride the static-shape
    decode step).  Traced (jitted serving path) OOB pos must be clamped
    so the garbage write lands in the slot's OWN last table entry — the
    scratch page 0 for an idle, all-zero table row — never via an
    undefined OOB table read into a live request's pages."""
    b, hkv, d, page, n = 2, 2, 32, 4, 2
    kp, vp, table = _random_paged_cache(31, b, n, page, hkv, d, 1 + b * n)
    table = table.at[1].set(0)  # row 1 idle: back to the scratch page
    ks = jax.random.split(K(32), 2)
    kn = jax.random.normal(ks[0], (b, hkv, d))
    vn = jax.random.normal(ks[1], (b, hkv, d))
    pos = jnp.asarray([2, n * page + 57], dtype=jnp.int32)
    before_k = np.asarray(kp)
    append = jax.jit(
        lambda *a: paged_kv_append(*a, interpret=True)
    )  # traced operands: the concrete range-check cannot fire
    k2, v2 = append(kn, vn, kp, vp, table, pos)
    k2 = np.asarray(k2)
    tab = np.asarray(table)
    # live row 0: written exactly where expected
    np.testing.assert_array_equal(k2[tab[0, 0], 2], np.asarray(kn)[0])
    # idle row 1: only the scratch page may have changed — every other
    # pool page is bitwise identical apart from row 0's single write
    untouched = [
        pid for pid in range(1, kp.shape[0]) if pid != tab[0, 0]
    ]
    np.testing.assert_array_equal(k2[untouched], before_k[untouched])


def test_paged_wrapper_validation():
    b, hkv, d, page, n = 2, 2, 64, 8, 2
    kp, vp, table = _random_paged_cache(27, b, n, page, hkv, d, 1 + b * n)
    q = jax.random.normal(K(28), (b, 4, d))
    kv = jnp.asarray([3, 5], dtype=jnp.int32)
    with pytest.raises(TypeError, match="integer-typed"):
        paged_decode_attention(q, kp, vp, table.astype(jnp.float32), kv,
                               interpret=True)
    with pytest.raises(ValueError, match="exceeds the cache"):
        # kv_len beyond what the table can address
        paged_decode_attention(q, kp, vp, table,
                               jnp.asarray([n * page + 1, 0]), interpret=True)
    with pytest.raises(ValueError, match="exceeds the cache"):
        # page id beyond the pool
        bad = table.at[0, 0].set(kp.shape[0])
        paged_decode_attention(q, kp, vp, bad, kv, interpret=True)
    with pytest.raises(ValueError, match="page_table must be"):
        paged_decode_attention(q, kp, vp, table[0], kv, interpret=True)
    with pytest.raises(ValueError, match="exceeds the cache"):
        # concrete append position past the slot's table capacity
        kn = jax.random.normal(K(29), (b, hkv, d))
        paged_kv_append(kn, kn, kp, vp, table,
                        jnp.asarray([0, n * page]), interpret=True)


# ---------------------------------------------------------------------------
# moe gating
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "n,e,k,cap,block_n",
    [
        (256, 8, 2, 48, 128),    # contended capacity
        (512, 8, 2, 1024, 256),  # dropless
        (256, 128, 1, 4, 128),   # llama4-style: 128 experts top-1
        (128, 16, 2, 24, 128),   # jamba-style
        (512, 4, 2, 128, 64),    # small E, many blocks
    ],
)
def test_moe_gating_matches_ref(n, e, k, cap, block_n):
    logits = jax.random.normal(K(4), (n, e))
    ki, gi, pi, mi = moe_gating(logits, top_k=k, capacity=cap,
                                block_n=block_n, interpret=True)
    kr, gr, pr, mr = moe_gating_ref(logits, top_k=k, capacity=cap,
                                    block_n=block_n)
    np.testing.assert_array_equal(np.asarray(ki), np.asarray(kr))
    np.testing.assert_allclose(np.asarray(gi), np.asarray(gr), rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(pi), np.asarray(pr))
    np.testing.assert_array_equal(np.asarray(mi), np.asarray(mr))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), e=st.sampled_from([4, 8, 16]))
def test_moe_gating_invariants(seed, e):
    """Invariants: gates sum to 1; positions within an expert are unique;
    kept positions < capacity; top-1 choice has the max prob."""
    n, k, cap = 128, 2, 16
    logits = jax.random.normal(K(seed), (n, e))
    idx, gates, pos, keep = moe_gating(logits, top_k=k, capacity=cap,
                                       block_n=64, interpret=True)
    idx, gates, pos, keep = map(np.asarray, (idx, gates, pos, keep))
    np.testing.assert_allclose(gates.sum(axis=1), 1.0, rtol=1e-5)
    assert (pos[keep] < cap).all()
    # per-expert uniqueness of assigned positions
    for ee in range(e):
        taken = pos[(idx == ee)]
        assert len(np.unique(taken)) == len(taken)
    # rank-0 really is the argmax
    probs = np.asarray(jax.nn.softmax(logits, axis=-1))
    np.testing.assert_array_equal(idx[:, 0], probs.argmax(axis=1))


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,t,h,p,n,chunk",
    [
        (1, 128, 2, 64, 64, 32),
        (2, 256, 4, 32, 128, 64),
        (1, 64, 8, 64, 16, 16),   # jamba-ish small state
        (2, 128, 1, 128, 128, 128),  # single chunk == T
    ],
)
def test_ssd_kernel_matches_sequential(b, t, h, p, n, chunk, dtype):
    ks = jax.random.split(K(5), 4)
    x = jax.random.normal(ks[0], (b, t, h, p), dtype=dtype)
    a = jax.nn.sigmoid(jax.random.normal(ks[1], (b, t, h))).astype(dtype)
    B = jax.random.normal(ks[2], (b, t, n), dtype=dtype)
    C = jax.random.normal(ks[3], (b, t, n), dtype=dtype)
    y_k, s_k = ssd_chunked(x, a, B, C, chunk, interpret=True)
    y_r, s_r = ssd_sequential_ref(x, a, B, C)
    tol = dict(rtol=2e-4, atol=2e-4) if dtype == jnp.float32 else dict(rtol=5e-2, atol=5e-2)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), **tol)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), **tol)


def test_ssd_chunked_ref_matches_sequential_with_state():
    """The model-layer chunked path (used in the dry-run) also equals the
    sequential scan, including a nonzero initial state."""
    ks = jax.random.split(K(6), 5)
    b, t, h, p, n, chunk = 2, 128, 2, 32, 64, 32
    x = jax.random.normal(ks[0], (b, t, h, p))
    a = jax.nn.sigmoid(jax.random.normal(ks[1], (b, t, h)))
    B = jax.random.normal(ks[2], (b, t, n))
    C = jax.random.normal(ks[3], (b, t, n))
    s0 = jax.random.normal(ks[4], (b, h, n, p))
    y_c, s_c = ssd_chunked_ref(x, a, B, C, chunk, initial_state=s0)
    y_s, s_s = ssd_sequential_ref(x, a, B, C, initial_state=s0)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_s), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_s), rtol=2e-4, atol=2e-4)
    # kernel path with initial state (wrapper folds it in linearly)
    y_k, s_k = ssd_chunked(x, a, B, C, chunk, initial_state=s0, interpret=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_s), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_s), rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_ssd_state_linearity_property(seed):
    """SSD is linear in x: scan(2x) == 2*scan(x)."""
    ks = jax.random.split(K(seed), 4)
    b, t, h, p, n = 1, 64, 2, 16, 16
    x = jax.random.normal(ks[0], (b, t, h, p))
    a = jax.nn.sigmoid(jax.random.normal(ks[1], (b, t, h)))
    B = jax.random.normal(ks[2], (b, t, n))
    C = jax.random.normal(ks[3], (b, t, n))
    y1, s1 = ssd_chunked(x, a, B, C, 16, interpret=True)
    y2, s2 = ssd_chunked(2 * x, a, B, C, 16, interpret=True)
    np.testing.assert_allclose(np.asarray(y2), 2 * np.asarray(y1), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), 2 * np.asarray(s1), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# tcmm assignment
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "n,m,f,n_valid",
    [(512, 64, 4, 64), (1024, 512, 8, 100), (256, 16, 128, 16), (512, 128, 4, 1)],
)
def test_tcmm_assign_matches_ref(n, m, f, n_valid, dtype):
    ks = jax.random.split(K(7), 2)
    pts = jax.random.normal(ks[0], (n, f), dtype=dtype) * 3
    cents = jax.random.normal(ks[1], (m, f), dtype=dtype) * 3
    valid = jnp.arange(m) < n_valid
    idx_k, d_k = tcmm_assign(pts, cents, valid, block_n=256, interpret=True)
    idx_r, d_r = tcmm_assign_ref(pts, cents, valid)
    tol = dict(rtol=1e-4, atol=1e-4) if dtype == jnp.float32 else dict(rtol=5e-2, atol=5e-1)
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_r), **tol)
    if dtype == jnp.float32:
        np.testing.assert_array_equal(np.asarray(idx_k), np.asarray(idx_r))
    assert (np.asarray(idx_k) < n_valid).all()  # never picks invalid rows


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_tcmm_assign_exact_match_property(seed):
    """A point equal to a valid centroid must map to it with distance ~0."""
    ks = jax.random.split(K(seed), 1)[0]
    m, f = 32, 4
    cents = jax.random.normal(ks, (m, f)) * 5
    pts = jnp.tile(cents[7][None], (64, 1))
    valid = jnp.ones((m,), dtype=bool)
    idx, d = tcmm_assign(pts, cents, valid, block_n=64, interpret=True)
    assert (np.asarray(idx) == 7).all()
    np.testing.assert_allclose(np.asarray(d), 0.0, atol=1e-4)

"""Serving layer: prefill/decode steps, continuous batcher semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_arch
from repro.models.zoo import build_model
from repro.serving.batcher import ContinuousBatcher, Request
from repro.serving.serve_step import make_decode_step, make_prefill_step


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("llama3.2-1b", smoke=True)
    model = build_model(cfg, compute_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def greedy_reference(model, params, prompt, n_new):
    """Reference decode: rerun the full forward for every new token."""
    toks = list(prompt)
    out = []
    for _ in range(n_new):
        logits, _ = model.train_logits(
            params, {"tokens": jnp.asarray(toks, dtype=jnp.int32)[None]}
        )
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        toks.append(nxt)
    return out


def test_batcher_matches_full_forward_decoding(setup):
    cfg, model, params = setup
    prompts = [[5, 9, 2], [7, 1, 1, 3], [11]]
    n_new = 5
    b = ContinuousBatcher(model, params, slots=2, max_len=32)
    for p in prompts:
        b.submit(Request(prompt=p, max_new_tokens=n_new))
    b.run_until_drained()
    assert len(b.completed) == 3
    by_prompt = {tuple(r.prompt): r.output for r in b.completed}
    for p in prompts:
        ref = greedy_reference(model, params, p, n_new)
        assert by_prompt[tuple(p)] == ref, f"prompt {p}"


def test_batcher_continuous_admission(setup):
    """More requests than slots: queue drains as slots free (continuous
    batching), every request completes exactly once."""
    cfg, model, params = setup
    b = ContinuousBatcher(model, params, slots=2, max_len=32)
    reqs = [Request(prompt=[i + 2, i + 3], max_new_tokens=3) for i in range(7)]
    for r in reqs:
        b.submit(r)
    assert b.queue_depth() == 7
    b.run_until_drained()
    assert len(b.completed) == 7
    assert sorted(r.req_id for r in b.completed) == sorted(r.req_id for r in reqs)
    assert all(len(r.output) == 3 for r in b.completed)
    assert b.occupancy() == 0


def test_batcher_eos_frees_slot_early(setup):
    cfg, model, params = setup
    # discover the first greedy token for a probe prompt, use it as "EOS"
    probe = greedy_reference(model, params, [4, 4], 1)[0]
    b = ContinuousBatcher(model, params, slots=1, max_len=32, eos_token=probe)
    b.submit(Request(prompt=[4, 4], max_new_tokens=10))
    b.run_until_drained()
    (done,) = b.completed
    assert done.output[-1] == probe
    assert len(done.output) < 10  # stopped early on EOS


def test_prefill_step_returns_argmax(setup):
    cfg, model, params = setup
    prefill = make_prefill_step(model)
    toks = jnp.asarray([[3, 5, 7, 9]], dtype=jnp.int32)
    cache = model.init_cache(1, 16)
    nxt, cache2 = prefill(params, {"tokens": toks}, cache)
    logits, _ = model.train_logits(params, {"tokens": toks})
    assert int(nxt[0]) == int(jnp.argmax(logits[0, -1]))
    # cache positions advanced
    flat = jax.tree.leaves(
        jax.tree.map(lambda x: x, cache2)
    )
    assert any((np.asarray(x) == 4).all() for x in flat if np.asarray(x).ndim <= 2)


def test_paged_batcher_matches_dense_on_real_model(setup):
    """Device-side paging on a real transformer: the paged batcher (page
    pool + page tables + Pallas paged decode) produces exactly the tokens
    the dense full-forward reference does, and returns every page."""
    from repro.serving.kv_cache import PagedSpec

    cfg, model, params = setup
    prompts = [[5, 9, 2], [7, 1, 1, 3], [11]]
    n_new = 5
    paged = PagedSpec(num_pages=1 + 2 * 4, page_size=8)  # 2 slots x 32/8
    b = ContinuousBatcher(model, params, slots=2, max_len=32, paged=paged)
    for p in prompts:
        b.submit(Request(prompt=p, max_new_tokens=n_new))
    b.run_until_drained()
    assert len(b.completed) == 3
    by_prompt = {tuple(r.prompt): r.output for r in b.completed}
    for p in prompts:
        assert by_prompt[tuple(p)] == greedy_reference(model, params, p, n_new)
    assert b.page_pool.in_use == 0
    assert b.page_pool.leaked() == 0


def test_paged_release_resets_device_cache_pos(setup):
    """Regression: a freed slot's device-cache ``pos`` used to keep the
    finished request's length and then grow every tick the slot idled,
    eventually walking the kv-append page-table lookup off the slot's
    row.  Releasing a slot must zero its pos across every layer cache."""
    from jax.tree_util import DictKey, tree_flatten_with_path

    from repro.serving.kv_cache import PagedSpec

    cfg, model, params = setup
    paged = PagedSpec(num_pages=1 + 4, page_size=8)
    b = ContinuousBatcher(model, params, slots=1, max_len=32, paged=paged)
    b.submit(Request(prompt=[5, 9, 2], max_new_tokens=4))
    b.run_until_drained()
    assert len(b.completed) == 1
    pos_leaves = [
        leaf for path, leaf in tree_flatten_with_path(b.cache)[0]
        if any(isinstance(p, DictKey) and p.key == "pos" for p in path)
    ]
    assert pos_leaves, "paged transformer cache must carry pos leaves"
    for leaf in pos_leaves:
        assert int(jnp.max(jnp.abs(leaf))) == 0

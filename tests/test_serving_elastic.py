"""Elastic serving loop: autoscaled occupancy, replica scale-out, bounded
ingress backpressure, chaos-kill re-admission, and admission-policy
selection — all deterministic via the arithmetic stub model (no weights)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.elastic import AutoscalerConfig, split_units
from repro.core.messages import Mailbox, Message
from repro.core.scheduler import DeadlineScheduler, make_scheduler
from repro.models.stub import StubModel
from repro.serving import ContinuousBatcher, ElasticServingPool, Request


@pytest.fixture(scope="module")
def stub():
    model = StubModel()
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def greedy_reference(model, params, prompt, n_new):
    toks = list(prompt)
    out = []
    for _ in range(n_new):
        logits, _ = model.train_logits(
            params, {"tokens": jnp.asarray(toks, dtype=jnp.int32)[None]}
        )
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        toks.append(nxt)
    return out


def make_pool(stub, **kwargs):
    model, params = stub
    defaults = dict(slots_per_replica=2, max_replicas=2, initial_units=1,
                    heartbeat_timeout=3.0)
    defaults.update(kwargs)
    return ElasticServingPool(model, params, **defaults)


# --- building blocks ----------------------------------------------------------


def test_split_units_fills_replicas_first():
    assert split_units(1, 4) == [1]
    assert split_units(4, 4) == [4]
    assert split_units(5, 4) == [4, 1]
    assert split_units(8, 4) == [4, 4]
    assert split_units(0, 4) == [1]  # never below one unit


def test_mailbox_try_put_and_put_front():
    box = Mailbox("t", capacity=2)
    assert box.try_put(Message(topic="x", payload=1))
    assert box.try_put(Message(topic="x", payload=2))
    assert not box.try_put(Message(topic="x", payload=3))  # full: no raise
    assert box.dropped == 1
    box.put_front(Message(topic="x", payload=0))  # re-admission ignores cap
    assert box.depth() == 3
    assert box.get().payload == 0


def test_deadline_scheduler_orders_by_urgency():
    sched = make_scheduler("edf")
    assert isinstance(sched, DeadlineScheduler)
    lax = Message(topic="s", payload=Request(prompt=[1], deadline=50.0))
    urgent = Message(topic="s", payload=Request(prompt=[2], deadline=1.0))
    none = Message(topic="s", payload=Request(prompt=[3]))
    assert [m.payload.deadline for m in sched.order([lax, none, urgent])] == [
        1.0, 50.0, None,
    ]
    # priority breaks in when no deadline is set (higher = sooner)
    hi = Message(topic="s", payload=Request(prompt=[4], priority=9))
    assert sched.order([none, hi])[0] is hi
    # ...but any deadline outranks bare priority, and negative priority
    # yields even to neutral traffic
    bg = Message(topic="s", payload=Request(prompt=[5], priority=-5))
    assert [m.payload.prompt[0] for m in sched.order([bg, hi, none, lax])] \
        == [1, 4, 3, 5]  # deadline, then hi-pri, neutral, deprioritized
    assert [m.payload.prompt[0] for m in sched.order([hi, urgent])] == [2, 4]


def test_stub_batcher_matches_full_forward(stub):
    """Anchor: continuous batching over the stub reproduces the reference
    token-for-token, so every pool test below checks real decode output."""
    model, params = stub
    b = ContinuousBatcher(model, params, slots=2, max_len=32)
    prompts = [[5, 9, 2], [7, 1], [11]]
    for p in prompts:
        b.submit(Request(prompt=p, max_new_tokens=5))
    b.run_until_drained()
    assert len(b.completed) == 3
    for r in b.completed:
        assert r.output == greedy_reference(model, params, r.prompt, 5)


def test_occupancy_target_caps_admission(stub):
    model, params = stub
    b = ContinuousBatcher(model, params, slots=4, max_len=32)
    b.set_target_occupancy(2)
    for i in range(6):
        b.submit(Request(prompt=[i + 1], max_new_tokens=4))
    b.step()
    assert b.occupancy() == 2  # half the static slots stay idle
    b.set_target_occupancy(4)
    b.step()
    assert b.occupancy() == 4
    b.run_until_drained()
    assert len(b.completed) == 6


# --- elasticity ---------------------------------------------------------------


def test_autoscaler_scales_occupancy_up_and_back_down(stub):
    """Acceptance: a burst drives the slot-unit target from 1 to the
    maximum (spawning a second replica) and idleness brings it back."""
    pool = make_pool(stub)
    for i in range(24):
        pool.submit(Request(prompt=[i % 5 + 1], max_new_tokens=6), now=0.0)
    now = 1.0
    for _ in range(200):
        if pool.queue_depth() == 0 and pool.occupancy() == 0:
            break
        pool.step(now)
        now += 1.0
    # a few idle steps so the scale-in side of the hysteresis fires
    for _ in range(3):
        pool.step(now)
        now += 1.0
    targets = [t for (_, t, _, _) in pool.occupancy_log]
    occupancies = [o for (_, _, o, _) in pool.occupancy_log]
    replicas = [n for (_, _, _, n) in pool.occupancy_log]
    assert max(targets) == 4, targets          # scaled out to the cap
    assert targets[-1] == 1, targets           # and back down after the spike
    assert max(occupancies) >= 3               # the slots actually filled
    assert occupancies[-1] == 0
    assert max(replicas) == 2                  # true scale-out, not one box
    assert len(pool.completed) == 24
    model, params = stub
    for r in pool.completed:
        assert r.output == greedy_reference(model, params, r.prompt, 6)


def test_scale_in_drains_without_cancelling(stub):
    pool = make_pool(stub, initial_units=4)  # start wide: 2 replicas
    assert len(pool.active_replicas()) == 2
    reqs = [Request(prompt=[i + 1], max_new_tokens=10) for i in range(4)]
    for r in reqs:
        pool.submit(r, now=0.0)
    now = 1.0
    for _ in range(50):
        if pool.queue_depth() == 0 and pool.occupancy() == 0:
            break
        pool.step(now)
        now += 1.0
    # backlog/worker fell below the low watermark long before the decode
    # budget ran out: replicas drained away, yet every request completed.
    assert len(pool.completed) == 4
    assert pool.metrics.value("serve.replica_draining") >= 1
    assert all(len(r.output) == 10 for r in pool.completed)


# --- backpressure -------------------------------------------------------------


def test_bounded_ingress_sheds_overflow(stub):
    pool = make_pool(stub, ingress_capacity=3, overflow="shed")
    accepted = [
        pool.submit(Request(prompt=[1], max_new_tokens=2), now=0.0)
        for _ in range(8)
    ]
    assert sum(accepted) == 3
    assert pool.metrics.value("serve.shed") == 5
    assert len(pool.shed) == 5
    pool.run_until_drained()
    assert len(pool.completed) == 3  # shed requests are gone for good


def test_defer_mode_rejects_without_dropping(stub):
    pool = make_pool(stub, ingress_capacity=2, overflow="defer")
    assert pool.submit(Request(prompt=[1], max_new_tokens=2), now=0.0)
    assert pool.submit(Request(prompt=[2], max_new_tokens=2), now=0.0)
    req = Request(prompt=[3], max_new_tokens=2)
    assert not pool.submit(req, now=0.0)          # caller owns the retry
    assert pool.metrics.value("serve.deferred") == 1
    assert not pool.shed
    pool.step(1.0)                                 # frees ingress space
    assert pool.submit(req, now=1.0)               # retry now fits
    pool.run_until_drained(now=2.0)
    assert len(pool.completed) == 3


# --- resilience ---------------------------------------------------------------


def test_replica_kill_readmits_and_completes_exactly_once(stub):
    model, params = stub
    pool = make_pool(stub, initial_units=4, heartbeat_timeout=2.0)
    reqs = [Request(prompt=[i % 5 + 1], max_new_tokens=8) for i in range(12)]
    for r in reqs:
        pool.submit(r, now=0.0)
    now = 1.0
    for _ in range(3):
        pool.step(now)
        now += 1.0
    killed = pool.kill_replica(0)
    assert pool.occupancy() > 0, "work must be in flight at the kill"
    for _ in range(100):
        if pool.queue_depth() == 0 and pool.occupancy() == 0:
            break
        pool.step(now)
        now += 1.0
    assert len(pool.completed) == 12
    assert sorted(r.req_id for r in pool.completed) == sorted(
        r.req_id for r in reqs
    )
    assert pool.metrics.value("serve.replica_restarts") == 1
    assert pool.metrics.value("serve.readmitted") > 0
    assert any(r.restarts > 0 for r in pool.completed)
    assert any(e[1] == "restarted" and e[2] == killed
               for e in pool.supervisor.events)
    # re-decoded from scratch: outputs still exact
    for r in pool.completed:
        assert r.output == greedy_reference(model, params, r.prompt, 8)


def test_kill_before_first_step_still_recovers(stub):
    """A replica killed before it ever heartbeats must still be detected
    (detectors are seeded at supervise time) — no trapped requests."""
    pool = make_pool(stub, heartbeat_timeout=2.0)
    req = Request(prompt=[3], max_new_tokens=3)
    pool.submit(req, now=0.0)
    pool.kill_replica(0)  # before any pool.step
    now = 1.0
    for _ in range(50):
        if pool.queue_depth() == 0 and pool.occupancy() == 0:
            break
        pool.step(now)
        now += 1.0
    assert len(pool.completed) == 1
    assert pool.metrics.value("serve.replica_restarts") == 1


def test_deferred_retry_keeps_latency_clock(stub):
    """enqueued_at is stamped at the first submit attempt, so the wait in
    a defer-retry loop shows up in the measured latency."""
    pool = make_pool(stub, ingress_capacity=1, overflow="defer")
    first = Request(prompt=[1], max_new_tokens=2)
    parked = Request(prompt=[2], max_new_tokens=2)
    assert pool.submit(first, now=0.0)
    assert not pool.submit(parked, now=0.0)   # rejected, clock started
    pool.step(1.0)
    assert pool.submit(parked, now=5.0)       # retried much later
    pool.run_until_drained(now=6.0)
    assert parked.enqueued_at == 0.0          # not reset by the retry
    assert parked.completed_at - parked.enqueued_at >= 6.0


# --- admission policies -------------------------------------------------------


@pytest.mark.parametrize("policy,expected", [
    ("fcfs", "round_robin"),
    ("round_robin", "round_robin"),
    ("jsq", "jsq"),
    ("pow2", "pow2"),
    ("edf", "edf"),
])
def test_policy_selection(stub, policy, expected):
    pool = make_pool(stub, policy=policy)
    assert pool.scheduler.name == expected
    assert pool.policy_name == policy


def test_unknown_policy_rejected(stub):
    with pytest.raises(ValueError):
        make_pool(stub, policy="lifo")


def test_load_aware_policy_beats_fcfs_tail_with_straggler(stub):
    """Acceptance: on a bursty open-loop trace against a pool with one
    slow replica, JSQ's p99 completion time beats blind FCFS round-robin
    (bench_serving sweeps this across seeds; one seed suffices here)."""
    model, params = stub

    def p99(policy):
        pool = ElasticServingPool(
            model, params, slots_per_replica=4, max_replicas=4,
            initial_units=16, policy=policy,
            replica_queue_capacity=64,
            replica_speeds=[1.0, 1.0, 1.0, 0.25],
            autoscaler=AutoscalerConfig(high_watermark=1e9, low_watermark=-1.0),
            heartbeat_timeout=1e12,
        )
        rng = np.random.default_rng(0)
        arrivals = []
        for t in range(240):
            rate = 2.2 if 40 <= t < 100 else 0.9
            for _ in range(rng.poisson(rate)):
                arrivals.append(
                    (t, [int(x) for x in rng.integers(1, 90, 2)],
                     int(rng.integers(2, 24)))
                )
        i, t = 0, 0
        while i < len(arrivals) or pool.queue_depth() or pool.occupancy():
            while i < len(arrivals) and arrivals[i][0] <= t:
                _, prompt, n = arrivals[i]
                pool.submit(Request(prompt=prompt, max_new_tokens=n),
                            now=float(t))
                i += 1
            pool.step(float(t))
            t += 1
            assert t < 5000
        lat = [r.completed_at - r.enqueued_at for r in pool.completed]
        return float(np.percentile(lat, 99))

    assert p99("jsq") < p99("fcfs")


def test_edf_urgent_request_overtakes_lax_backlog(stub):
    """One slot, three queued requests: under EDF the late-submitted but
    urgent request decodes first; under FCFS it decodes last."""
    def completion_order(policy):
        pool = make_pool(stub, slots_per_replica=1, max_replicas=1,
                         initial_units=1)
        pool.scheduler = make_scheduler(policy)
        lax1 = Request(prompt=[1], max_new_tokens=4, deadline=100.0)
        lax2 = Request(prompt=[2], max_new_tokens=4, deadline=100.0)
        urgent = Request(prompt=[3], max_new_tokens=4, deadline=1.0)
        for r in (lax1, lax2, urgent):
            pool.submit(r, now=0.0)
        pool.run_until_drained(now=1.0)
        return [r.req_id for r in pool.completed], (lax1, lax2, urgent)

    order_edf, (l1, _, urgent) = completion_order("edf")
    assert order_edf[0] == urgent.req_id
    order_fcfs, (l1, _, urgent) = completion_order("fcfs")
    assert order_fcfs[0] == l1.req_id
    assert order_fcfs[-1] == urgent.req_id

"""Discrete-event simulator: reproduce the paper's findings (scaled down
for CI speed) and assert the simulator's own invariants."""

import pytest

from repro.core.simulation import (
    FailureConfig,
    ReactiveSimConfig,
    SimEngine,
    WorkloadConfig,
    simulate_liquid,
    simulate_reactive,
)

pytestmark = pytest.mark.slow  # heavy sweep/compile module: excluded from tier-1

# Backlog must outlast the run (as in the paper, which streams a large
# dataset): Liquid drains ~160k in 600s, Reactive ~2x that.
WL = WorkloadConfig(total_messages=400_000, partitions=3)
DUR = 600.0


def test_engine_ordering():
    eng = SimEngine()
    seen = []
    eng.schedule(2.0, lambda: seen.append("b"))
    eng.schedule(1.0, lambda: seen.append("a"))
    eng.schedule(1.0, lambda: seen.append("a2"))  # FIFO among equal times
    eng.run_until(10.0)
    assert seen == ["a", "a2", "b"]
    assert eng.now == 10.0


class TestPaperFindings:
    """The paper's §4 claims, each as an executable assertion."""

    @pytest.fixture(scope="class")
    def results(self):
        return {
            "l3": simulate_liquid(3, WL, DUR),
            "l6": simulate_liquid(6, WL, DUR),
            "r": simulate_reactive(
                WL, DUR, config=ReactiveSimConfig(initial_tasks=6)
            ),
        }

    def test_f1_liquid_task_limit(self, results):
        """Fig. 8: Liquid with 6 tasks == Liquid with 3 tasks (3 partitions)."""
        assert results["l6"].processed == results["l3"].processed

    def test_f1_reactive_throughput_wins(self, results):
        """Fig. 8/9: Reactive Liquid total processed > both Liquid variants."""
        assert results["r"].processed > 1.3 * results["l3"].processed

    def test_f3_completion_time_regression(self, results):
        """Fig. 11: paper-faithful (RR, unbounded) completion time is WORSE
        than Liquid — the honest negative result."""
        assert results["r"].mean_completion() > 5 * results["l3"].mean_completion()

    def test_f2_failure_resilience(self, results):
        """Fig. 10: under failures Reactive loses less than Liquid."""
        fc = FailureConfig(probability=0.6, interval=60.0, restart_delay=30.0, seed=3)
        l3f = simulate_liquid(3, WL, DUR, failures=fc)
        rf = simulate_reactive(
            WL, DUR, failures=fc, config=ReactiveSimConfig(initial_tasks=6)
        )
        liquid_loss = 1 - l3f.processed / results["l3"].processed
        reactive_loss = 1 - rf.processed / results["r"].processed
        assert rf.restarts > 0  # the supervisor actually healed things
        assert reactive_loss < liquid_loss

    def test_beyond_paper_scheduler_fixes_completion(self, results):
        """Our §5 fix: JSQ + bounded mailboxes ~Liquid completion time while
        keeping the throughput win."""
        rb = simulate_reactive(
            WL,
            DUR,
            config=ReactiveSimConfig(
                initial_tasks=6, scheduler="jsq", mailbox_capacity=4, elastic=False
            ),
        )
        assert rb.processed > 1.3 * results["l3"].processed  # keeps throughput
        assert rb.mean_completion() < 3 * results["l3"].mean_completion()
        assert rb.mean_completion() < 0.05 * results["r"].mean_completion()


def test_eq1_liquid_completion_shape():
    """Eq. (1): within a batch of n, completion of the i-th message is
    n*t_c + i*t_p — so max/min ratio within early batches ~ n."""
    wl = WorkloadConfig(
        total_messages=300, partitions=1, batch_n=10, growth_alpha=0.0
    )
    res = simulate_liquid(1, wl, 600.0, num_nodes=1, cores=1)
    assert res.processed == 300
    first_batch = sorted(res.completion_times)[:10]
    expected_first = wl.batch_n * wl.t_consume + wl.t_process0
    assert first_batch[0] == pytest.approx(expected_first, rel=0.05)


def test_capacity_is_physical():
    """Aggregate throughput can never exceed cores/t_process."""
    wl = WorkloadConfig(
        total_messages=1_000_000, partitions=3, growth_alpha=0.0
    )
    res = simulate_reactive(
        wl, 300.0, num_nodes=3, cores=2,
        config=ReactiveSimConfig(initial_tasks=12),
    )
    max_rate = 6 / wl.t_process0
    assert res.processed <= max_rate * 300.0 * 1.01


def test_failure_injection_counts():
    wl = WorkloadConfig(total_messages=10_000, partitions=3)
    fc = FailureConfig(probability=1.0, interval=50.0, restart_delay=20.0)
    res = simulate_liquid(3, wl, 300.0, failures=fc)
    assert res.failures >= 3  # every node fails at least once


def test_reactive_deterministic_given_seed():
    wl = WorkloadConfig(total_messages=30_000, partitions=3)
    fc = FailureConfig(probability=0.5, seed=7)
    a = simulate_reactive(wl, 400.0, failures=fc)
    b = simulate_reactive(wl, 400.0, failures=fc)
    assert a.processed == b.processed
    assert a.timeline == b.timeline

"""Virtual-time reproduction of the paper's findings (scaled down for CI
speed), now driven through the *live* actuator: ``simulate_reactive``
builds a real ``ReactiveJob`` on a ``Cluster`` and steps it on the event
heap — these tests therefore assert the shipped system, not a restated
control loop (``simulate_liquid`` stays the pinned-task baseline)."""

import pytest

from repro.core.simulation import (
    FailureConfig,
    ReactiveSimConfig,
    SimEngine,
    WorkloadConfig,
    simulate_liquid,
    simulate_reactive,
)

pytestmark = pytest.mark.slow  # heavy sweep/compile module: excluded from tier-1

# Backlog must outlast the run (as in the paper, which streams a large
# dataset): physical capacity is 3 nodes x 2 cores / t_p = 600 msg/s, so
# 300 s can drain at most 180k of the 200k preloaded messages.
WL = WorkloadConfig(total_messages=200_000, partitions=3)
DUR = 300.0


def test_engine_ordering():
    eng = SimEngine()
    seen = []
    eng.schedule(2.0, lambda: seen.append("b"))
    eng.schedule(1.0, lambda: seen.append("a"))
    eng.schedule(1.0, lambda: seen.append("a2"))  # FIFO among equal times
    eng.run_until(10.0)
    assert seen == ["a", "a2", "b"]
    assert eng.now == 10.0


class TestPaperFindings:
    """The paper's §4 claims, each as an executable assertion."""

    @pytest.fixture(scope="class")
    def results(self):
        return {
            "l3": simulate_liquid(3, WL, DUR),
            "l6": simulate_liquid(6, WL, DUR),
            "r": simulate_reactive(
                WL, DUR, config=ReactiveSimConfig(initial_tasks=6)
            ),
        }

    def test_f1_liquid_task_limit(self, results):
        """Fig. 8: Liquid with 6 tasks == Liquid with 3 tasks (3 partitions)."""
        assert results["l6"].processed == results["l3"].processed

    def test_f1_reactive_throughput_wins(self, results):
        """Fig. 8/9: Reactive Liquid total processed > both Liquid variants."""
        assert results["r"].processed > 1.3 * results["l3"].processed

    def test_f3_completion_time_regression(self, results):
        """Fig. 11: paper-faithful (RR, unbounded) completion time is WORSE
        than Liquid — the honest negative result."""
        assert results["r"].mean_completion() > 5 * results["l3"].mean_completion()

    def test_f2_failure_resilience(self, results):
        """Fig. 10: under failures Reactive loses less than Liquid, and
        the supervisor (the live pool's, not a simulator copy) heals.
        The failure cadence is the paper's scaled 10:5 interval:restart
        ratio, with the rebalance pause scaled alike."""
        fc = FailureConfig(probability=0.6, interval=60.0, restart_delay=30.0, seed=0)
        l3f = simulate_liquid(3, WL, DUR, failures=fc, rebalance_pause=3.0)
        rf = simulate_reactive(
            WL, DUR, failures=fc, config=ReactiveSimConfig(initial_tasks=6)
        )
        liquid_loss = 1 - l3f.processed / results["l3"].processed
        reactive_loss = 1 - rf.processed / results["r"].processed
        assert rf.restarts > 0  # the supervisor actually healed things
        assert reactive_loss < liquid_loss

    def test_f2b_liquid_superlinear_degradation(self):
        """Fig. 10: Liquid's degradation is super-linear in p — restarted
        members rebuild in-memory state from history (no state service),
        and at p=90% the rebuilds stop fitting in the gaps between
        failures, so loss at p=90% exceeds 3x the p=30% loss (linear
        scaling would be exactly 3x).  Liquid-only, so the paper's full
        cadence ratios fit in a fast event-heap run."""
        wl = WorkloadConfig(total_messages=2_000_000, partitions=3)
        base = simulate_liquid(3, wl, 3600.0).processed
        losses = {}
        for p in (0.3, 0.9):
            fc = FailureConfig(probability=p, interval=120.0,
                               restart_delay=60.0, seed=2)
            lf = simulate_liquid(3, wl, 3600.0, failures=fc,
                                 rebalance_pause=6.0)
            losses[p] = 1 - lf.processed / base
        assert losses[0.9] > 0
        # linear degradation would give losses[0.9] == 3 * losses[0.3]
        assert losses[0.9] > 3 * losses[0.3]

    def test_beyond_paper_scheduler_fixes_completion(self, results):
        """Our §5 fix: JSQ + bounded mailboxes ~Liquid completion time while
        keeping the throughput win."""
        rb = simulate_reactive(
            WL,
            DUR,
            config=ReactiveSimConfig(
                initial_tasks=6, scheduler="jsq", mailbox_capacity=4, elastic=False
            ),
        )
        assert rb.processed > 1.3 * results["l3"].processed  # keeps throughput
        assert rb.mean_completion() < 3 * results["l3"].mean_completion()
        assert rb.mean_completion() < 0.05 * results["r"].mean_completion()


def test_eq1_liquid_completion_shape():
    """Eq. (1): within a batch of n, completion of the i-th message is
    n*t_c + i*t_p — so max/min ratio within early batches ~ n."""
    wl = WorkloadConfig(
        total_messages=300, partitions=1, batch_n=10, growth_alpha=0.0
    )
    res = simulate_liquid(1, wl, 600.0, num_nodes=1, cores=1)
    assert res.processed == 300
    first_batch = sorted(res.completion_times)[:10]
    expected_first = wl.batch_n * wl.t_consume + wl.t_process0
    assert first_batch[0] == pytest.approx(expected_first, rel=0.05)


def test_capacity_is_physical():
    """Aggregate throughput can never exceed cores/t_process — the live
    pool's co-residency dilation enforces the core budget even with
    twice as many tasks as cores."""
    wl = WorkloadConfig(
        total_messages=250_000, partitions=3, growth_alpha=0.0
    )
    res = simulate_reactive(
        wl, 300.0, num_nodes=3, cores=2,
        config=ReactiveSimConfig(initial_tasks=12),
    )
    max_rate = 6 / wl.t_process0
    assert res.processed <= max_rate * 300.0 * 1.01


def test_failure_injection_counts():
    wl = WorkloadConfig(total_messages=10_000, partitions=3)
    fc = FailureConfig(probability=1.0, interval=50.0, restart_delay=20.0)
    res = simulate_liquid(3, wl, 300.0, failures=fc)
    assert res.failures >= 3  # every node fails at least once


def test_reactive_deterministic_given_seed():
    wl = WorkloadConfig(total_messages=30_000, partitions=3)
    fc = FailureConfig(probability=0.5, interval=60.0, restart_delay=30.0, seed=7)
    a = simulate_reactive(wl, 200.0, failures=fc)
    b = simulate_reactive(wl, 200.0, failures=fc)
    assert a.processed == b.processed
    assert a.timeline == b.timeline
    assert a.restarts == b.restarts

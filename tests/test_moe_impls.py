"""Equivalence of the two MoE dispatch implementations (the einsum
baseline vs the scatter §Perf optimization), including under capacity
drops, plus the context switch."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import MoEConfig
from repro.models.moe import (
    init_moe,
    moe_apply,
    moe_ffn,
    moe_ffn_scatter,
    moe_implementation,
)
from repro.config import get_arch
from repro.models.zoo import build_model

pytestmark = pytest.mark.slow  # heavy sweep/compile module: excluded from tier-1


def setup(e=4, k=2, d=32, ff=64, cap_factor=0.0, seed=0):
    from repro.config.base import ArchConfig

    cfg = ArchConfig(
        name="t", family="moe", num_layers=1, d_model=d, num_heads=4,
        num_kv_heads=2, d_ff=ff, vocab_size=64,
        moe=MoEConfig(num_experts=e, top_k=k, capacity_factor=cap_factor),
    )
    params = init_moe(jax.random.PRNGKey(seed), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 16, d))
    return cfg, params, x


@pytest.mark.parametrize("e,k", [(4, 2), (8, 1), (16, 2)])
def test_scatter_matches_einsum_dropless(e, k):
    cfg, params, x = setup(e=e, k=k, cap_factor=0.0)
    y1, a1 = moe_ffn(params, x, cfg.moe)
    y2, a2 = moe_ffn_scatter(params, x, cfg.moe)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)
    assert float(a1) == pytest.approx(float(a2), rel=1e-5)


def test_scatter_matches_einsum_with_drops():
    """Under contention both implementations must drop the SAME token
    choices (rank-major FCFS contract)."""
    cfg, params, x = setup(e=4, k=2, cap_factor=0.6)
    y1, _ = moe_ffn(params, x, cfg.moe)
    y2, _ = moe_ffn_scatter(params, x, cfg.moe)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)


def test_einsum_token_major_vs_scatter_rank_major_documented():
    """The einsum path assigns capacity token-major; the scatter path
    rank-major (kernel contract). With drops the two orders CAN differ —
    this test pins the fact that we chose identical inputs where they
    agree; the semantic difference is documented in moe.py."""
    # (agreement on the contended case above is the real assertion;
    # here: both are deterministic across calls)
    cfg, params, x = setup(e=4, k=2, cap_factor=0.5, seed=3)
    y1a, _ = moe_ffn_scatter(params, x, cfg.moe)
    y1b, _ = moe_ffn_scatter(params, x, cfg.moe)
    np.testing.assert_array_equal(np.asarray(y1a), np.asarray(y1b))


def test_moe_apply_context_switch():
    cfg, params, x = setup()
    y_default, _ = moe_apply(params, x, cfg.moe)
    with moe_implementation("scatter"):
        y_scatter, _ = moe_apply(params, x, cfg.moe)
    np.testing.assert_allclose(np.asarray(y_default), np.asarray(y_scatter),
                               rtol=1e-5, atol=1e-5)
    with pytest.raises(ValueError):
        with moe_implementation("nope"):
            pass


def test_full_model_forward_same_under_both_impls():
    cfg = get_arch("mixtral-8x7b", smoke=True)
    model = build_model(cfg, compute_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab_size)
    l1, _ = model.train_logits(params, {"tokens": toks})
    with moe_implementation("scatter"):
        l2, _ = model.train_logits(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=2e-4, atol=2e-4)


def test_scatter_grads_flow():
    cfg, params, x = setup()

    def loss(p):
        y, aux = moe_ffn_scatter(p, x, cfg.moe)
        return jnp.sum(y ** 2) + aux

    grads = jax.grad(loss)(params)
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0

"""Degrade property-test modules to smoke tests when hypothesis is absent.

``hypothesis`` is an optional dev dependency (see requirements-dev.txt).
Test modules import ``given``/``settings``/``st`` from here instead of from
hypothesis directly: when the real package is installed they are passed
through untouched; when it is missing, ``given`` marks each property test
skipped (same effect as ``pytest.importorskip``, but per-test, so the
module's non-hypothesis smoke tests still collect and run) and ``st`` is a
stub whose strategy constructors accept anything and return placeholders.
"""

import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - environment dependent
    HAVE_HYPOTHESIS = False

    class _StubStrategies:
        """st.anything(...) — including @st.composite — yields the stub."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _StubStrategies()

    def given(*args, **kwargs):
        del args, kwargs
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*args, **kwargs):
        del args, kwargs
        return lambda fn: fn

"""Paged serving hot path (DESIGN §6): PagePool accounting, paged
continuous batching (token-exact under page pressure), the gang-admission
static baseline, oversize fail-fast, the chaos-kill zero-leaked-pages
regression, and split-prefill bitwise replay — all deterministic via the
arithmetic stub model (no weights)."""

import jax
import jax.numpy as jnp
import pytest

from repro.data.topics import MessageLog
from repro.models.stub import StubModel
from repro.serving import (
    ContinuousBatcher,
    ElasticServingPool,
    PagePool,
    PagedSpec,
    Request,
    ServingJob,
)


@pytest.fixture(scope="module")
def stub():
    model = StubModel()
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def greedy_reference(model, params, prompt, n_new):
    toks = list(prompt)
    out = []
    for _ in range(n_new):
        logits, _ = model.train_logits(
            params, {"tokens": jnp.asarray(toks, dtype=jnp.int32)[None]}
        )
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        toks.append(nxt)
    return out


# --- PagePool unit tests ------------------------------------------------------


def test_page_pool_basic_accounting():
    pool = PagePool(PagedSpec(num_pages=9, page_size=8))
    assert pool.capacity == 8  # page 0 is reserved, never allocatable
    ids = pool.alloc(3)
    assert len(ids) == 3 and 0 not in ids
    assert pool.in_use == 3 and pool.available == 5
    assert pool.high_watermark == 3
    pool.free(ids)
    assert pool.in_use == 0 and pool.available == 8
    assert pool.leaked() == 0
    assert pool.high_watermark == 3  # watermark survives the free


def test_page_pool_alloc_is_all_or_nothing():
    pool = PagePool(PagedSpec(num_pages=5, page_size=8))  # 4 usable
    held = pool.alloc(3)
    assert held is not None
    before = (pool.available, pool.in_use)
    assert pool.alloc(2) is None  # only 1 left: grant nothing at all
    assert (pool.available, pool.in_use) == before
    assert pool.alloc_failures == 1
    assert pool.alloc(1) is not None  # the remaining page is still grantable


def test_page_pool_double_free_raises():
    pool = PagePool(PagedSpec(num_pages=4, page_size=8))
    ids = pool.alloc(2)
    pool.free(ids)
    with pytest.raises(ValueError, match="double-free"):
        pool.free(ids)
    with pytest.raises(ValueError, match="double-free"):
        pool.free([0])  # the scratch page is never allocated, never freed


def test_page_pool_never_hands_out_scratch_page():
    pool = PagePool(PagedSpec(num_pages=6, page_size=4))
    ids = pool.alloc(pool.capacity)
    assert sorted(ids) == [1, 2, 3, 4, 5]
    assert pool.alloc(1) is None  # truly exhausted


def test_page_pool_pages_for_and_fits():
    pool = PagePool(PagedSpec(num_pages=5, page_size=8))  # 4 usable
    assert pool.pages_for(0) == 0
    assert pool.pages_for(1) == 1
    assert pool.pages_for(8) == 1
    assert pool.pages_for(9) == 2
    assert pool.fits(32) and not pool.fits(33)


def test_paged_spec_validation():
    with pytest.raises(ValueError, match="num_pages"):
        PagedSpec(num_pages=1, page_size=8)  # no room for the scratch page
    with pytest.raises(ValueError, match="page_size"):
        PagedSpec(num_pages=4, page_size=0)


# --- paged continuous batching (stub model) -----------------------------------


def make_batcher(stub, num_pages, page_size=4, **kwargs):
    model, params = stub
    defaults = dict(slots=4, max_len=32)
    defaults.update(kwargs)
    spec = PagedSpec(num_pages=num_pages, page_size=page_size)
    return ContinuousBatcher(model, params, paged=spec, **defaults)


def test_paged_batcher_token_exact_ample_pool(stub):
    model, params = stub
    b = make_batcher(stub, num_pages=33)  # every slot can hold max_len
    reqs = [Request(prompt=[i % 5 + 1, i % 3 + 2], max_new_tokens=6)
            for i in range(8)]
    for r in reqs:
        b.submit(r)
    b.run_until_drained()
    assert len(b.completed) == 8
    for r in b.completed:
        assert r.output == greedy_reference(model, params, r.prompt, 6)
    assert b.page_pool.in_use == 0
    assert b.page_pool.leaked() == 0
    assert b.preemptions == 0  # ample pool: nothing ever evicted


def test_paged_batcher_tight_pool_preempts_but_stays_exact(stub):
    """8 usable pages for 4 slots x 8 requests: the pool is under real
    pressure — admissions stall, running slots get preempted and
    recomputed — yet every output is token-exact and no page leaks."""
    model, params = stub
    b = make_batcher(stub, num_pages=9)  # 8 usable pages, page_size 4
    reqs = [Request(prompt=[i % 5 + 1, i % 3 + 2, 4], max_new_tokens=10)
            for i in range(8)]
    for r in reqs:
        b.submit(r)
    b.run_until_drained()
    assert len(b.completed) == 8
    for r in b.completed:
        assert r.output == greedy_reference(model, params, r.prompt, 10)
    assert b.preemptions + b.admit_stalls > 0, "the pool was never tight"
    assert b.page_pool.in_use == 0
    assert b.page_pool.leaked() == 0
    assert b.page_pool.high_watermark <= b.page_pool.capacity


def test_per_request_gang_admission_runs_in_waves(stub):
    """The static-batching baseline: a new batch may only form once every
    slot of the old one finished — completions land in distinct waves
    (what the decode bench's speedup is measured against)."""
    model, params = stub
    b = ContinuousBatcher(model, params, slots=2, max_len=32,
                          admission="per_request")
    reqs = [Request(prompt=[i + 2], max_new_tokens=4) for i in range(4)]
    for r in reqs:
        b.submit(r)
    for tick in range(100):
        if b.occupancy() == 0 and b.queue_depth() == 0:
            break
        b.step(float(tick))
    assert len(b.completed) == 4
    for r in b.completed:
        assert r.output == greedy_reference(model, params, r.prompt, 4)
    waves = sorted({r.completed_at for r in b.completed})
    assert len(waves) == 2, f"gang admission must form 2 waves, got {waves}"


def test_paged_oversize_request_fails_fast(stub):
    """A request that could never fit the pool (even with every page to
    itself) completes empty instead of livelocking through preemption."""
    model, params = stub
    b = make_batcher(stub, num_pages=3, slots=2)  # 2 usable pages = 8 tokens
    ok = Request(prompt=[3, 1], max_new_tokens=4)       # 6 tokens: fits
    huge = Request(prompt=[2, 5, 1, 4], max_new_tokens=20)  # 24 tokens: never
    b.submit(ok)
    b.submit(huge)
    b.run_until_drained()
    assert len(b.completed) == 2
    by_id = {r.req_id: r for r in b.completed}
    assert by_id[huge.req_id].output == []
    assert b.rejected_oversize == 1
    assert by_id[ok.req_id].output == greedy_reference(
        model, params, ok.prompt, 4
    )
    assert b.page_pool.in_use == 0 and b.page_pool.leaked() == 0


def test_empty_and_overlong_prompts_fail_fast(stub):
    """Regression: an empty prompt used to build a zero-page PagedSpec
    (crashing the tick with 'num_pages must be >= 2') and a prompt past
    max_len overran the scratch page-table width.  Both are unservable
    at any pool state — they complete empty instead of crashing, and
    well-formed neighbors are unaffected."""
    model, params = stub
    b = make_batcher(stub, num_pages=33, slots=2, max_len=16)
    ok = Request(prompt=[3, 1], max_new_tokens=4)
    empty = Request(prompt=[], max_new_tokens=4)
    long = Request(prompt=[1] * 16, max_new_tokens=4)  # == max_len: no room
    for r in (empty, ok, long):
        b.submit(r)
    b.run_until_drained()
    assert len(b.completed) == 3
    by_id = {r.req_id: r for r in b.completed}
    assert by_id[empty.req_id].output == []
    assert by_id[long.req_id].output == []
    assert b.rejected_invalid == 2
    assert by_id[ok.req_id].output == greedy_reference(
        model, params, ok.prompt, 4
    )
    assert b.page_pool.in_use == 0 and b.page_pool.leaked() == 0
    # the dense (non-paged) batcher takes the same guard
    d = ContinuousBatcher(model, params, slots=1, max_len=16)
    d.submit(Request(prompt=[], max_new_tokens=2))
    d.run_until_drained()
    assert d.rejected_invalid == 1 and d.completed[0].output == []


def test_stalled_queue_keeps_arrival_order(stub):
    """Regression: a preempted request used to requeue at the TAIL of
    the stalled list while failed admissions went to the head — the
    oldest in-flight request queued behind younger arrivals and became
    the repeat preemption victim.  Stalling must keep arrival order no
    matter which path parked the request."""
    from repro.core.messages import Message

    b = make_batcher(stub, num_pages=9)
    old = Request(prompt=[1], max_new_tokens=2)
    young = Request(prompt=[2], max_new_tokens=2)
    old.enqueued_at, young.enqueued_at = 0.0, 1.0
    # a failed admission parks the younger request first...
    b._stall(Message(topic="serve", payload=young, created_at=1.0))
    # ...then the older running request is preempted: it must go ahead.
    b._stall(Message(topic="serve", payload=old, created_at=0.0))
    assert [m.payload.req_id for m in b._stalled] == [
        old.req_id, young.req_id
    ]
    assert b._next_message().payload.req_id == old.req_id


# --- chaos regression: Let-It-Crash must return pages -------------------------


def test_chaos_kill_mid_decode_leaks_no_pages(stub):
    """Kill a replica while its slots hold pages: the supervisor drains
    the dead replica (freeing its pages) and re-admits the work; once the
    pool drains, zero pages remain allocated anywhere and every request
    completed exactly once, token-exact."""
    model, params = stub
    spec = PagedSpec(num_pages=17, page_size=4)  # 16 usable per replica
    pool = ElasticServingPool(
        model, params, paged=spec, slots_per_replica=2, max_replicas=2,
        initial_units=4, heartbeat_timeout=2.0,
    )
    reqs = [Request(prompt=[i % 5 + 1], max_new_tokens=8) for i in range(10)]
    for r in reqs:
        pool.submit(r, now=0.0)
    now = 1.0
    for _ in range(3):
        pool.step(now)
        now += 1.0
    assert pool.total_pages_in_use() > 0, "kill must land mid-decode"
    pool.kill_replica(0)
    pool.run_until_drained(now=now)
    assert sorted(r.req_id for r in pool.completed) == sorted(
        r.req_id for r in reqs
    )
    for r in pool.completed:
        assert r.output == greedy_reference(
            model, params, r.prompt, r.max_new_tokens
        )
    assert pool.metrics.value("serve.replica_restarts") == 1
    # the zero-leak invariant, pool-wide and per-replica
    assert pool.total_pages_in_use() == 0
    for replica in pool.replicas:
        assert replica.page_pool.leaked() == 0


# --- prefill/decode disaggregation --------------------------------------------


def make_job(stub, **kwargs):
    model, params = stub
    defaults = dict(partitions=2, slots_per_replica=2, max_replicas=2,
                    initial_units=2, heartbeat_timeout=3.0)
    defaults.update(kwargs)
    return ServingJob(model, params, **defaults)


def test_split_prefill_pins_first_token(stub):
    """The prefill stage durably pins first_token into the prefilled
    topic; decode trusts it, and responses stay token-exact."""
    model, params = stub
    job = make_job(stub, split_prefill=True)
    reqs = [Request(prompt=[i % 5 + 1, 2], max_new_tokens=5)
            for i in range(6)]
    for r in reqs:
        job.submit(r, now=0.0)
    job.run_until_drained(now=1.0)
    resp = job.responses()
    assert sorted(r["req_id"] for r in resp) == sorted(r.req_id for r in reqs)
    for r in resp:
        ref = greedy_reference(model, params, r["prompt"], 5)
        assert r["output"] == ref
    assert job.metrics.value("prefill.prompts") == 6
    pinned = [
        m.payload for part in job.log.get("prefilled").partitions
        for m in part.read(0, part.end_offset())
    ]
    assert len(pinned) == 6
    for p in pinned:
        assert p["first_token"] == greedy_reference(
            model, params, p["prompt"], 1
        )[0]


def test_split_prefill_empty_prompt_rejected_not_wedged(stub):
    """An empty prompt must not crash the prefill-stage worker (which
    would wedge it in a Let-It-Crash retry loop): it forwards unpinned
    and the decode batcher rejects it with an empty response, while
    neighbors decode token-exact."""
    model, params = stub
    job = make_job(stub, split_prefill=True)
    bad = Request(prompt=[], max_new_tokens=3)
    ok = Request(prompt=[2], max_new_tokens=3)
    job.submit(bad, now=0.0)
    job.submit(ok, now=0.0)
    job.run_until_drained(now=1.0)
    resp = {r["req_id"]: r for r in job.responses()}
    assert resp[bad.req_id]["output"] == []
    assert resp[ok.req_id]["output"] == greedy_reference(
        model, params, [2], 3
    )


def test_split_prefill_replay_bitwise_identical(stub, tmp_path):
    """Acceptance: kill the whole process mid-decode under split-prefill
    + paged KV, rebuild from the spilled topics + journals, and the
    committed response prefix is bitwise identical — same payloads, same
    offsets — with every request completing exactly once and zero pages
    left allocated."""
    import os

    model, params = stub
    d = str(tmp_path / "serve-log")
    jdir = os.path.join(d, "journals")
    spec = PagedSpec(num_pages=17, page_size=4)
    job1 = make_job(stub, spill_dir=d, journal_dir=jdir, split_prefill=True,
                    paged=spec)
    # Long heads hold the commit watermark back while short tails finish
    # out of order — the window where a naive replay double-decodes.
    # Explicit req_ids pin the key-hash partition placement.
    reqs = [
        Request(prompt=[i % 5 + 1], max_new_tokens=20 if i < 2 else 4,
                req_id=2_000_000 + i)
        for i in range(10)
    ]
    for r in reqs:
        job1.submit(r, now=0.0)
    now = 1.0
    for _ in range(10):  # partial progress, then the process "dies"
        job1.step(now)
        now += 1.0
    phase1 = job1.responses()
    assert 0 < len(phase1) < len(reqs), "kill must land mid-flight"
    committed1 = job1.committed_offsets()
    job1.close()  # heap state (ingress, replicas, page pools) is GONE

    log2 = MessageLog.reopen(d)
    job2 = make_job(stub, log=log2, journal_dir=jdir, split_prefill=True,
                    paged=spec)
    assert job2.committed_offsets() == committed1
    job2.run_until_drained(now=100.0)

    resp = job2.responses()
    # the committed prefix replays bitwise identically
    assert resp[: len(phase1)] == phase1
    ids = [r["req_id"] for r in resp]
    assert sorted(set(ids)) == sorted(r.req_id for r in reqs)
    assert len(ids) == len(set(ids)), "a request completed twice"
    by_id = {r["req_id"]: r for r in resp}
    for req in reqs:
        assert by_id[req.req_id]["output"] == greedy_reference(
            model, params, req.prompt, req.max_new_tokens
        )
    assert job2.pool.total_pages_in_use() == 0
    for replica in job2.pool.replicas:
        assert replica.page_pool.leaked() == 0

"""Multi-tenant fleet (ISSUE 10 tentpole): cost-weighted packing on the
shared cluster, cross-pool priority preemption (force-drain, zero page
leak, re-admission), per-tenant shedding with attributed responses, and
the max-register metrics that surface per-tenant peaks."""

import jax
import pytest

from repro.core.cluster import Cluster
from repro.core.elastic import AutoscalerConfig
from repro.data.topics import MessageLog
from repro.models.stub import StubModel
from repro.serving import ElasticServingPool, FleetManager, Request, TenantSpec
from repro.telemetry.metrics import MetricsHub, MetricsReplica


@pytest.fixture(scope="module")
def stub():
    model = StubModel()
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _specs(stub, **overrides):
    """Two-tenant default: cheap/high-priority vs expensive/low."""
    model, params = stub
    base = dict(model=model, params=params, slots=2, max_len=32,
                slo_ticks=50.0)
    hi = dict(base, name="hi", priority=1, cost=0.25, weight=0.5,
              max_replicas=6)
    lo = dict(base, name="lo", priority=0, cost=1.0, weight=2.0,
              max_replicas=3)
    hi.update(overrides.get("hi", {}))
    lo.update(overrides.get("lo", {}))
    return [TenantSpec(**hi), TenantSpec(**lo)]


# --- cost-weighted packing ----------------------------------------------------


def test_weighted_assign_packs_cheap_beside_expensive():
    cluster = Cluster(2, cores=2)
    a, b = cluster.nodes
    cluster.assign(a, "lo:replica0", weight=2.0)
    # least-loaded placement now prefers the empty node for the next
    # heavyweight, but three lightweights fit beside the heavyweight
    # before the loads even out
    cluster.assign(cluster.place(), "lo:replica1", weight=2.0)
    assert cluster.node_of("lo:replica1") is b
    for i in range(3):
        cluster.assign(cluster.place(), f"hi:replica{i}", weight=0.5)
    assert cluster.weight_of("hi:replica0") == 0.5
    # 2.0 + k*0.5 loads: the cheap replicas co-reside with expensive ones
    assert cluster.coresident_nodes() == 2
    assert cluster.total_cores() == 4
    cluster.audit()


def test_weight_rebinding_and_release_keep_loads_consistent():
    cluster = Cluster(2, cores=2)
    a, b = cluster.nodes
    cluster.assign(a, "x", weight=1.5)
    cluster.assign(b, "x", weight=0.5)   # move + reweigh in one call
    assert a.load == 0.0 and b.load == 0.5
    cluster.release("x")
    assert b.load == 0.0 and cluster.weight_of("x") == 1.0  # default
    cluster.audit()


# --- cross-pool preemption (ElasticPool.preempt_worker) ----------------------


def _busy_pool(stub, replicas=3):
    model, params = stub
    pool = ElasticServingPool(
        model, params, slots_per_replica=2, max_len=32,
        max_replicas=replicas, initial_units=2 * replicas,
        # hold the autoscaler still: this test drives scale by hand
        autoscaler=AutoscalerConfig(high_watermark=1e9, low_watermark=-1.0),
        paged=TenantSpec(name="t", model=model, params=params,
                         slots=2, max_len=32).paged_spec(),
        name="t",
    )
    for i in range(6):
        assert pool.submit(Request(prompt=[1 + i, 2, 3],
                                   max_new_tokens=8), now=0.0)
    pool.step(0.0)  # spawn replicas, admit, decode one tick
    return pool


def test_preempt_replica_force_drains_and_readmits(stub):
    pool = _busy_pool(stub)
    assert len(pool.active_replicas()) >= 2
    in_flight = pool.occupancy()
    assert in_flight > 0
    target_before = pool.pool.controller.target_size
    victim = pool.preempt_replica()
    assert victim is not None and victim.startswith("t:replica")
    # the victim's pages are freed the moment it drains — no leak window
    assert all(r.page_pool.leaked() == 0 for r in pool.replicas
               if r.page_pool is not None)
    # its work re-admitted (ingress front or another replica), not lost
    assert pool.queue_depth() + pool.occupancy() >= in_flight - 0
    # the controller target dropped so reconcile won't respawn the unit
    assert pool.pool.controller.target_size < target_before
    assert pool.pool.merged_metrics().value("serve.replica_preemptions") == 1
    # nothing dropped: everything still completes
    for t in range(1, 200):
        pool.step(float(t))
        if pool.queue_depth() == 0 and pool.occupancy() == 0:
            break
    assert len(pool.completed) == 6
    assert all(r.output for r in pool.completed)
    assert pool.total_pages_in_use() == 0


def test_preempt_never_takes_the_last_replica(stub):
    model, params = stub
    pool = ElasticServingPool(model, params, slots_per_replica=2,
                              max_len=32, max_replicas=2, initial_units=2)
    pool.submit(Request(prompt=[1, 2], max_new_tokens=4), now=0.0)
    pool.step(0.0)
    assert len(pool.active_replicas()) == 1
    assert pool.preempt_replica() is None  # degrade, never starve


# --- the fleet end-to-end -----------------------------------------------------


def test_fleet_burst_preempts_low_priority_tenant(stub):
    fm = FleetManager(_specs(stub), num_nodes=3, cores=2, mode="fleet")
    # warm the low-priority tenant into multiple replicas
    for t in range(8):
        for _ in range(4):
            fm.submit("lo", [1, 2, 3], now=float(t), max_new_tokens=6)
        fm.step(float(t))
    assert len(fm.tenants["lo"].pool.active_replicas()) >= 2
    # now the high-priority tenant bursts far past its share
    for t in range(8, 20):
        for _ in range(10):
            fm.submit("hi", [4, 5], now=float(t), max_new_tokens=6)
        fm.step(float(t))
    assert fm.preemptions >= 1
    assert fm.tenants["lo"].granted < fm.tenants["lo"].spec.max_replicas
    # preemption degraded lo but never starved it
    assert len(fm.tenants["lo"].pool.active_replicas()) >= 1
    fm.run_until_drained(now=20.0)
    assert fm.pending_work() == 0
    assert fm.total_pages_in_use() == 0
    # every submitted request was answered durably, tenant-attributed
    for name, s in fm.tenants.items():
        part = s.responses.partitions[0]
        msgs = part.read(0, part.end_offset())
        assert len(msgs) == s.submitted
        assert all(m.payload["tenant"] == name for m in msgs)


def test_fleet_sheds_expired_requests_with_attribution(stub):
    fm = FleetManager(_specs(stub, hi={"slo_ticks": 2.0}),
                      num_nodes=2, cores=2)
    fm.submit("hi", [1, 2, 3], now=0.0, max_new_tokens=4)
    # the deadline (0 + 2.0) passes before the request is ever fed
    fm.step(10.0)
    s = fm.tenants["hi"]
    assert s.shed == 1 and s.slo_missed == 1
    part = s.responses.partitions[0]
    (msg,) = part.read(0, part.end_offset())
    assert msg.payload["fail_reason"] == "shed"
    assert msg.payload["tenant"] == "hi"
    assert msg.payload["slo_met"] is False
    assert msg.payload["output"] == []
    assert fm.run_until_drained() >= 0
    assert fm.tenants["hi"].pool.metrics.value("serve.shed_expired") == 1


def test_fleet_oversize_fail_fast_is_tenant_attributed(stub):
    # pages=2 -> one usable page (16 tokens): a legal-length prompt that
    # still cannot fit even with the whole pool to itself fails fast
    fm = FleetManager(_specs(stub, lo={"pages": 2}), num_nodes=2, cores=2)
    fm.submit("lo", list(range(20)), now=0.0, max_new_tokens=4)
    for t in range(5):
        fm.step(float(t))
    s = fm.tenants["lo"]
    part = s.responses.partitions[0]
    (msg,) = part.read(0, part.end_offset())
    assert msg.payload["fail_reason"] == "oversize"
    assert msg.payload["tenant"] == "lo"
    assert msg.payload["slo_met"] is False
    assert fm.merged_metrics().counter("serve.rejected_oversize") == 1


def test_fleet_chaos_kill_leaks_no_pages(stub):
    fm = FleetManager(_specs(stub), num_nodes=3, cores=2)
    for t in range(6):
        for _ in range(4):
            fm.submit("hi", [1, 2, 3, 4], now=float(t), max_new_tokens=6)
            fm.submit("lo", [5, 6], now=float(t), max_new_tokens=6)
        fm.step(float(t))
    killed = fm.kill_replica("hi", 0)
    assert killed.startswith("hi:replica")
    fm.run_until_drained(now=6.0)
    assert fm.pending_work() == 0
    assert fm.total_pages_in_use() == 0
    stats = fm.stats()
    assert stats["pages_in_use"] == 0
    for s in fm.tenants.values():
        assert s.completed + s.shed == s.submitted


def test_static_mode_partitions_and_never_preempts(stub):
    fm = FleetManager(_specs(stub), num_nodes=4, cores=2, mode="static")
    assert fm.cluster is None and len(fm.partitions) == 2
    for t in range(10):
        for _ in range(6):
            fm.submit("hi", [1, 2], now=float(t), max_new_tokens=4)
            fm.submit("lo", [3, 4], now=float(t), max_new_tokens=4)
        fm.step(float(t))
        # the private slice hard-caps lo at cores // weight = 2 replicas
        # no matter the backlog — static capacity is not fungible
        assert len(fm.tenants["lo"].pool.active_replicas()) <= 2
    assert fm.preemptions == 0
    fm.run_until_drained(now=10.0)
    assert fm.total_pages_in_use() == 0


def test_fleet_shared_log_and_duplicate_tenant_rejected(stub):
    log = MessageLog()
    fm = FleetManager(_specs(stub), num_nodes=2, cores=2, log=log)
    assert log.exists("hi.requests") and log.exists("lo.responses")
    model, params = stub
    with pytest.raises(ValueError, match="duplicate"):
        FleetManager([TenantSpec(name="x", model=model, params=params),
                      TenantSpec(name="x", model=model, params=params)])
    with pytest.raises(ValueError, match="mode"):
        FleetManager(_specs(stub), mode="bogus")
    del fm


# --- max-register metrics (satellite: per-tenant peaks over CRDT) ------------


def test_record_max_is_a_semilattice():
    a = MetricsReplica("a")
    b = MetricsReplica("b")
    a.record_max("peak", 3.0)
    a.record_max("peak", 1.0)   # lower: no-op
    b.record_max("peak", 5.0)
    b.record_max("only_b", 2.0)
    ab = a.merge(b)
    ba = b.merge(a)
    assert ab.peak("peak") == 5.0 == ba.peak("peak")      # commutative
    assert ab.peak("only_b") == 2.0
    assert a.merge(a).peak("peak") == 3.0                 # idempotent
    assert ab.merge(b).peak("peak") == 5.0                # absorbing
    assert a.peak("missing", default=-1.0) == -1.0


def test_metrics_hub_surfaces_peaks():
    hub = MetricsHub()
    r1 = MetricsReplica("r1")
    r1.record_max("serve.page_high_watermark", 7.0)
    r2 = MetricsReplica("r2")
    r2.record_max("serve.page_high_watermark", 4.0)
    hub.ingest(r1)
    hub.ingest(r2)
    assert hub.peak("serve.page_high_watermark") == 7.0
    assert hub.peak("absent") == 0.0


def test_fleet_stats_report_page_peaks(stub):
    fm = FleetManager(_specs(stub), num_nodes=2, cores=2)
    fm.submit("hi", [1, 2, 3], now=0.0, max_new_tokens=4)
    fm.step(0.0)
    fm.run_until_drained(now=1.0)
    stats = fm.stats()
    assert stats["tenants"]["hi"]["page_peak"] > 0
    assert stats["tenants"]["hi"]["slo_met"] == 1
    assert stats["slo_met_total"] == 1

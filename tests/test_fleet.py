"""Fleet-scale cluster/chaos layer (ISSUE 9): counter-based RNG streams,
topology-correlated failures, gray-failure ramps + straggler detection,
event coalescing, arrival profiles — and the scalar-vs-vectorized
bitwise-equivalence contract."""

import itertools
import random

import numpy as np
import pytest

from repro.core.cluster import (
    STREAM_NODE,
    Cluster,
    FailureConfig,
    FailureInjector,
    Topology,
    stream_uniform,
    stream_uniform_array,
)
from repro.core.messages import Message
from repro.core.pool import ElasticPool, WorkerBase
from repro.core.runtime import SimEngine, VirtualRuntime
from repro.core.simulation import (
    ReactiveSimConfig,
    WorkloadConfig,
    simulate_reactive,
)
from tests._hypothesis_support import given, settings, st


# --- counter-based RNG streams ------------------------------------------------


def test_stream_uniform_scalar_matches_vectorized_bitwise():
    for seed in (0, 1, 12345, 2**63):
        for k in (0, 1, 17, 10**6):
            streams = np.arange(257, dtype=np.uint64)
            vec = stream_uniform_array(seed, streams, k)
            ref = [stream_uniform(seed, s, k) for s in range(257)]
            assert vec.tolist() == ref  # bitwise, not approx


def test_stream_uniform_is_a_pure_counter_function():
    """Fleet-size / iteration-order invariance: node 7's draw at
    interval 3 is one number, no matter what else was drawn."""
    a = stream_uniform(9, STREAM_NODE + 7, 3)
    for _ in range(100):
        stream_uniform(9, STREAM_NODE + random.randrange(10**6), random.randrange(100))
    assert stream_uniform(9, STREAM_NODE + 7, 3) == a
    # distinct streams / intervals decorrelate
    assert a != stream_uniform(9, STREAM_NODE + 8, 3)
    assert a != stream_uniform(9, STREAM_NODE + 7, 4)


def test_failure_sequences_invariant_to_fleet_size():
    """Growing the fleet never perturbs an existing node's failures."""
    def downs(n_nodes):
        engine = SimEngine()
        cluster = Cluster(n_nodes, cores=2)
        seen = []
        FailureInjector(
            engine, cluster,
            FailureConfig(probability=0.4, interval=10.0, restart_delay=5.0,
                          seed=11),
            on_down=lambda node: seen.append((engine.now, node.node_id)),
        )
        engine.run_until(100.0)
        return seen

    small, big = downs(8), downs(64)
    assert [e for e in big if e[1] < 8] == small


# --- topology + correlated chaos ---------------------------------------------


def test_topology_domains_cover_and_partition():
    topo = Topology(22, nodes_per_rack=4, racks_per_zone=2)
    assert topo.num_racks == 6 and topo.num_zones == 3
    covered = []
    for r in range(topo.num_racks):
        covered.extend(topo.rack_members(r))
    assert covered == list(range(22))  # every node in exactly one rack
    for nid in range(22):
        assert nid in topo.rack_members(topo.rack_of(nid))
        assert nid in topo.zone_members(topo.zone_of(nid))
    assert len(list(topo.zone_members(2))) == 6  # ragged tail zone


def test_rack_burst_takes_down_whole_racks_and_restores():
    topo = Topology(12, nodes_per_rack=4, racks_per_zone=3)
    engine = SimEngine()
    cluster = Cluster(12, cores=2, topology=topo)
    inj = FailureInjector(
        engine, cluster,
        FailureConfig(interval=10.0, restart_delay=4.0, seed=0,
                      burst_probability=1.0, burst_scope="rack"),
    )
    engine.run_until(11.0)
    assert inj.bursts == 3 and inj.failures == 12
    assert not cluster.healthy()
    # racks die whole: every rack's members share the down state
    for r in range(topo.num_racks):
        assert all(not cluster.nodes[i].up for i in topo.rack_members(r))
    engine.run_until(15.0)
    assert len(cluster.healthy()) == 12 and inj.restores == 12


def test_zone_partition_cuts_whole_zone():
    topo = Topology(12, nodes_per_rack=2, racks_per_zone=3)  # 2 zones
    engine = SimEngine()
    cluster = Cluster(12, cores=2, topology=topo)
    inj = FailureInjector(
        engine, cluster,
        FailureConfig(interval=10.0, restart_delay=100.0, seed=0,
                      partition_probability=1.0, partition_duration=5.0),
    )
    engine.run_until(11.0)
    assert inj.partitions == 2 and not cluster.healthy()
    engine.run_until(16.0)  # partitions heal on their own (shorter) clock
    assert len(cluster.healthy()) == 12


def test_correlated_chaos_requires_topology():
    engine = SimEngine()
    cluster = Cluster(4, cores=2)  # no topology
    inj = FailureInjector(
        engine, cluster,
        FailureConfig(interval=5.0, seed=0, burst_probability=0.5),
    )
    with pytest.raises(ValueError, match="topology"):
        engine.run_until(6.0)


def test_gray_ramp_slows_then_restores_without_downtime():
    engine = SimEngine()
    cluster = Cluster(3, cores=2)
    inj = FailureInjector(
        engine, cluster,
        FailureConfig(interval=10.0, seed=0, gray_probability=1.0,
                      gray_speed=0.25, gray_duration=8.0),
    )
    engine.run_until(11.0)
    assert inj.gray_events == 3
    assert all(n.up for n in cluster.nodes), "gray nodes stay up"
    assert all(n.speed == 0.25 for n in cluster.nodes)
    assert cluster.nodes[0].dilation() == 4.0  # cache invalidated by ramp
    engine.run_until(19.0)
    # second tick at t=20 hasn't fired; the first ramps ended at t=18
    assert all(n.speed == 1.0 for n in cluster.nodes)
    engine.run_until(21.0)
    assert all(n.speed == 0.25 for n in cluster.nodes)  # ramped again


def test_restores_coalesce_into_one_event_per_delay():
    """A 100-node failure wave schedules O(1) restore events, not O(N)."""
    engine = SimEngine()
    cluster = Cluster(100, cores=2)
    FailureInjector(
        engine, cluster,
        FailureConfig(probability=1.0, interval=10.0, restart_delay=5.0, seed=0),
    )
    engine.run_until(10.0)  # the injector tick fired: 100 nodes down
    assert cluster.failures == 100
    # heap holds exactly: the next injector tick + ONE batched restore
    assert len(engine._heap) == 2
    engine.run_until(15.5)
    assert len(cluster.healthy()) == 100


# --- scalar vs vectorized: bitwise equivalence --------------------------------


def _mirrored_clusters(n=16, topo=True):
    topology = Topology(n, nodes_per_rack=4, racks_per_zone=2) if topo else None
    return (
        Cluster(n, cores=2, topology=topology, vectorize=False),
        Cluster(n, cores=2, topology=topology, vectorize=True),
    )


def _apply_ops(cluster, ops):
    """Replay an op list; returns the placement-decision trace."""
    trace = []
    for op, arg in ops:
        if op == "place":
            node = cluster.place()
            if node is not None:
                cluster.assign(node, f"c{arg}")
                trace.append(node.node_id)
        elif op == "release":
            cluster.release(f"c{arg}")
        elif op == "fail":
            trace.append(cluster.fail(cluster.nodes[arg % len(cluster.nodes)]))
        elif op == "restore":
            node = cluster.nodes[arg % len(cluster.nodes)]
            trace.append(int(cluster.restore(node)))
    return trace


def _assert_clusters_equal(scalar, vector):
    for a, b in zip(scalar.nodes, vector.nodes):
        assert (a.up, a.epoch, a.speed, sorted(a.residents)) == (
            b.up, b.epoch, b.speed, sorted(b.residents)
        )
        assert a.dilation() == b.dilation()
    assert scalar.failures == vector.failures
    assert scalar.total_residents() == vector.total_residents()
    scalar.audit()
    vector.audit()


def test_vectorized_placement_matches_scalar_random_ops():
    """Seeded randomized equivalence (always runs, hypothesis or not):
    arbitrary place/release/fail/restore sequences produce bitwise-equal
    placement decisions, epochs, dilations, and residency on both paths."""
    rng = random.Random(1234)
    for trial in range(30):
        scalar, vector = _mirrored_clusters()
        ops = [
            (rng.choice(["place", "place", "release", "fail", "restore"]),
             rng.randrange(40))
            for _ in range(rng.randrange(5, 120))
        ]
        assert _apply_ops(scalar, ops) == _apply_ops(vector, ops)
        _assert_clusters_equal(scalar, vector)


@settings(max_examples=50, deadline=None)
@given(
    ops=st.lists(
        st.tuples(
            st.sampled_from(["place", "release", "fail", "restore"]),
            st.integers(0, 40),
        ),
        min_size=1,
        max_size=80,
    )
)
def test_vectorized_placement_matches_scalar_property(ops):
    scalar, vector = _mirrored_clusters()
    assert _apply_ops(scalar, ops) == _apply_ops(vector, ops)
    _assert_clusters_equal(scalar, vector)


def test_vectorized_injector_matches_scalar_bitwise():
    """The numpy draw and the scalar loop fail the same nodes at the
    same intervals, burst the same racks, and gray the same nodes."""
    fc = FailureConfig(
        probability=0.3, interval=10.0, restart_delay=4.0, seed=7,
        burst_probability=0.2, burst_scope="rack",
        gray_probability=0.15, gray_speed=0.5, gray_duration=12.0,
    )
    states = {}
    for vec in (False, True):
        engine = SimEngine()
        topo = Topology(24, nodes_per_rack=4, racks_per_zone=3)
        cluster = Cluster(24, cores=2, topology=topo, vectorize=vec)
        events = []
        inj = FailureInjector(
            engine, cluster, fc,
            on_down=lambda n: events.append(("down", round(engine.now, 6), n.node_id)),
            on_up=lambda n: events.append(("up", round(engine.now, 6), n.node_id)),
        )
        engine.run_until(200.0)
        states[vec] = (
            events,
            [(n.up, n.epoch, n.speed) for n in cluster.nodes],
            (inj.failures, inj.restores, inj.bursts, inj.gray_events),
        )
    assert states[False] == states[True]


# --- chaos replay through VirtualRuntime --------------------------------------


def _fleet_sim(vectorize):
    wl = WorkloadConfig(
        total_messages=4000, partitions=4, growth_alpha=0.0,
        arrival_rate=4000 / 50.0,
    )
    fc = FailureConfig(
        probability=0.3, interval=12.0, restart_delay=6.0, seed=5,
        burst_probability=0.2, burst_scope="rack",
        gray_probability=0.2, gray_speed=0.3, gray_duration=15.0,
    )
    return simulate_reactive(
        wl, duration=60.0, num_nodes=12, cores=2, failures=fc,
        topology=Topology(12, nodes_per_rack=3, racks_per_zone=2),
        config=ReactiveSimConfig(
            initial_tasks=8, scheduler="round_robin",
            detect_timeout=3.0, restart_cost=2.0,
        ),
        vectorize=vectorize,
        straggler_threshold=2.5,
    )


def test_chaos_replay_is_deterministic_and_path_independent():
    """Same seed -> identical run; scalar and vectorized paths ->
    identical run (the end-to-end equivalence claim, through
    VirtualRuntime, injector, pool, and straggler detection at once)."""
    a, b = _fleet_sim(True), _fleet_sim(True)
    assert (a.processed, a.failures, a.restarts, a.timeline) == (
        b.processed, b.failures, b.restarts, b.timeline
    )
    s = _fleet_sim(False)
    assert (a.processed, a.failures, a.restarts, a.straggler_relocations,
            a.timeline) == (
        s.processed, s.failures, s.restarts, s.straggler_relocations,
        s.timeline
    )
    assert a.failures > 0 and a.restarts > 0  # the chaos actually bit


# --- straggler (gray-failure) detection in the pool ---------------------------


class _OneMsgWorker(WorkerBase):
    _ids = itertools.count()

    def __init__(self, sink):
        super().__init__(f"sw{next(_OneMsgWorker._ids)}")
        self.sink = sink

    def step(self, now: float = 0.0) -> int:
        msg = self.mailbox.get()
        if msg is None:
            return 0
        self.sink.append(msg.payload)
        return 1


def test_straggler_detection_relocates_off_gray_node():
    """A speed-ramped (gray) node passes liveness but starves its
    workers; symptom-based detection relocates them and excludes the
    gray node from the relocation's placement."""
    cluster = Cluster(3, cores=4)
    sink = []
    pool = ElasticPool(
        "gray",
        lambda: _OneMsgWorker(sink),
        scheduler="round_robin",
        initial_units=6,
        elastic=False,
        heartbeat_timeout=50.0,   # liveness never fires: only symptoms can
        cluster=cluster,
        restart_cost=1.0,
        straggler_threshold=2.0,
        straggler_patience=2,
        straggler_check_every=2,
    )
    gray = cluster.nodes[0]
    victims = {w.name for w in pool.workers if w.node is gray}
    assert victims
    cluster.set_speed(gray, 0.05)  # 20x slowdown, node stays up
    now = 0.0
    for r in range(200):
        for w in pool.workers:
            pool.route(Message(topic="t", payload=(r, w.name)))
        pool.step(now)
        now += 1.0
    relocations = pool.metrics.value("pool.straggler_relocations")
    assert relocations > 0
    assert all(w.node is not gray for w in pool.workers), (
        "workers still pinned to the gray node"
    )
    cluster.audit()


def test_straggler_detection_off_by_default():
    cluster = Cluster(2, cores=4)
    pool = ElasticPool(
        "nograystrag", lambda: _OneMsgWorker([]), initial_units=2,
        elastic=False, cluster=cluster, restart_cost=0.0,
    )
    cluster.set_speed(cluster.nodes[0], 0.05)
    for r in range(50):
        pool.step(float(r))
    assert pool.metrics.value("pool.straggler_relocations") == 0


# --- VirtualRuntime: coalescing + generalized fast-forward --------------------


class _CountJob:
    def __init__(self):
        self.steps = []

    def step(self, now: float = 0.0) -> int:
        self.steps.append(round(now, 6))
        return 0

    def backlog(self) -> int:
        return 0


def test_every_coalesces_same_cadence_handlers():
    job = _CountJob()
    rt = VirtualRuntime(job, dt=1.0)
    fired = []
    for i in range(50):
        rt.every(5.0, lambda i=i: fired.append((rt.engine.now, i)), start=5.0)
    # 50 handlers, ONE heap event for the whole cadence group
    assert len(rt.engine._heap) == 1
    rt.run_until(20.0)
    # each firing runs all 50 handlers in registration order
    assert [t for t, _ in fired] == [5.0] * 50 + [10.0] * 50 + [15.0] * 50 + [20.0] * 50
    assert [i for _, i in fired][:50] == list(range(50))


def test_every_different_phases_stay_correct_on_key_collision():
    """Two groups with one interval but different phases may collide on
    a future (interval, time) key — both must keep firing exactly."""
    job = _CountJob()
    rt = VirtualRuntime(job, dt=1.0)
    fired = []
    rt.every(4.0, lambda: fired.append(("a", rt.engine.now)), start=2.0)
    rt.every(4.0, lambda: fired.append(("b", rt.engine.now)), start=6.0)
    rt.run_until(14.5)
    assert [e for e in fired if e[0] == "a"] == [("a", t) for t in (2.0, 6.0, 10.0, 14.0)]
    assert [e for e in fired if e[0] == "b"] == [("b", t) for t in (6.0, 10.0, 14.0)]


def test_fast_forward_interleaves_exactly_with_foreign_events():
    """The inlined tick stretch stops at every foreign event; order and
    timestamps match the event-at-a-time semantics."""
    job = _CountJob()
    rt = VirtualRuntime(job, dt=1.0)
    log = []
    rt.every(7.0, lambda: log.append(("sampler", rt.engine.now)), start=7.0)
    rt.at(3.5, lambda: log.append(("oneshot", rt.engine.now)))
    stats = rt.run_until(21.0)
    assert stats.rounds == 22                       # ticks at 0..21
    assert job.steps == [float(t) for t in range(22)]
    assert log == [
        ("oneshot", 3.5),
        ("sampler", 7.0), ("sampler", 14.0), ("sampler", 21.0),
    ]
    # equal-timestamp race: the sampler (older heap entry) fired before
    # the tick at t=7/14/21 — verify by sequencing within job.steps
    assert job.steps.index(7.0) == 7  # tick at 7 still happened


def test_fast_forward_resumable_mid_chain():
    job = _CountJob()
    rt = VirtualRuntime(job, dt=1.0)
    rt.run_until(4.0)
    rt.run_until(9.0)
    assert job.steps == [float(t) for t in range(10)]


# --- arrival profiles ---------------------------------------------------------


def test_arrival_profiles_integrate_exactly():
    base = dict(total_messages=10**9, partitions=1, arrival_rate=100.0)
    const = WorkloadConfig(**base)
    assert const.arrived(10.0) == 1000
    diurnal = WorkloadConfig(**base, arrival_profile="diurnal",
                             diurnal_period=40.0, diurnal_amplitude=0.8)
    # over whole periods the sine integrates away
    assert diurnal.arrived(40.0) == const.arrived(40.0)
    assert diurnal.arrived(80.0) == const.arrived(80.0)
    # mid-period the wave leads the flat profile (sin > 0 first half)
    assert diurnal.arrived(20.0) > const.arrived(20.0)
    flash = WorkloadConfig(**base, arrival_profile="flash", flash_at=10.0,
                           flash_duration=5.0, flash_multiplier=5.0)
    assert flash.arrived(10.0) == const.arrived(10.0)
    assert flash.arrived(15.0) == 1500 + 4 * 500   # window adds (m-1)*r*dur
    assert flash.arrived(30.0) == 3000 + 2000
    # monotone non-decreasing everywhere
    for wl in (const, diurnal, flash):
        seq = [wl.arrived(t / 4) for t in range(200)]
        assert seq == sorted(seq)


def test_arrival_profile_unknown_raises():
    wl = WorkloadConfig(arrival_rate=10.0, arrival_profile="bogus")
    with pytest.raises(ValueError, match="bogus"):
        wl.arrived(1.0)


def test_constant_profile_available_unchanged():
    """The paper-regime partition arithmetic is bit-identical to the
    pre-profile code (int(rate*now/partitions), floored once)."""
    wl = WorkloadConfig(total_messages=1000, partitions=3, arrival_rate=7.0)
    for now in (0.0, 0.5, 1.0, 3.33, 100.0, 10**4):
        assert wl.available(400, now) == min(400, int(7.0 * now / 3))

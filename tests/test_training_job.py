"""Elastic training over the log (ISSUE 3 tentpole): DP trainer workers
under the shared ElasticPool control plane, fed by the ordered
manual-commit TokenPipeline — offsets commit only after the optimizer
step that consumed them is journaled, chaos kills heal bitwise-exactly,
and DP scaling is a live pool event that never loses stream position."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import TrainingConfig, get_arch
from repro.core.elastic import AutoscalerConfig
from repro.data.pipeline import PipelineConfig, TokenPipeline, build_token_log
from repro.models.zoo import build_model
from repro.training.job import TrainingJob
from repro.training.train_step import make_train_step

BATCH, SEQ, PARTS, DOCS = 4, 16, 3, 128


@pytest.fixture(scope="module")
def rig():
    """One model + one jit'd step shared by every job in the module, so
    bitwise comparisons see the identical executable."""
    cfg = get_arch("llama3.2-1b", smoke=True)
    tcfg = TrainingConfig(
        learning_rate=1e-3, warmup_steps=0, schedule="constant"
    )
    model = build_model(cfg, compute_dtype=jnp.float32)
    step_fn = jax.jit(make_train_step(model, tcfg))
    return cfg, tcfg, model, step_fn


def make_log(cfg, num_docs=DOCS):
    # doc_len == seq_len + 1: one document is exactly one training
    # sequence, so offset accounting is doc-granular (TokenSource is
    # pure in (seed, i) — a rebuilt process regenerates the same log).
    return build_token_log(cfg.vocab_size, num_docs, doc_len=SEQ + 1,
                           partitions=PARTS)


def make_job(rig, **kwargs):
    cfg, tcfg, model, step_fn = rig
    defaults = dict(batch_size=BATCH, seq_len=SEQ, dp=2, max_dp=4,
                    train_step_fn=step_fn)
    defaults.update(kwargs)
    log = defaults.pop("log", None) or make_log(cfg)
    return TrainingJob(model, cfg, tcfg, log, **defaults)


def params_of(job):
    return [np.asarray(x) for x in jax.tree.leaves(job.state.params)]


def assert_bitwise_equal(a_job, b_job):
    for a, b in zip(params_of(a_job), params_of(b_job)):
        assert np.array_equal(a, b), "params diverged (not bitwise equal)"


def assert_exact_consumption(job, steps, step_offsets=None):
    """Zero skipped, zero double-consumed: per-partition committed
    offsets are contiguous prefixes whose per-step deltas sum exactly to
    steps * batch documents."""
    step_offsets = step_offsets or job.step_offsets
    consumed = {p: 0 for p in range(PARTS)}
    prev = {p: 0 for p in range(PARTS)}
    for step in range(1, steps + 1):
        offs = step_offsets[step]
        for p, off in offs.items():
            assert off > prev[p], f"step {step} re-consumed partition {p}"
            consumed[p] += off - prev[p]
            prev[p] = off
    assert sum(consumed.values()) == steps * BATCH
    assert job.committed_offsets() == prev


def journaled_step_offsets(job):
    """step -> offsets from the durable journal (spans process lives);
    a step journaled in two lives must have re-derived the identical
    consumption — the no-skip/no-double guarantee across replay."""
    by_step = {}
    for ev in job.store.journal.all_events():
        if ev.kind != "step":
            continue
        offs = {int(k): v for k, v in ev.data["offsets"].items()}
        if ev.data["step"] in by_step:
            assert by_step[ev.data["step"]] == offs, \
                f"step {ev.data['step']} consumed different offsets on replay"
        by_step[ev.data["step"]] = offs
    return by_step


# --- the pipeline's ordered manual-commit mode --------------------------------


def test_ordered_pipeline_is_deterministic_and_commit_gated():
    cfg = get_arch("llama3.2-1b", smoke=True)
    pc = PipelineConfig(partitions=PARTS, batch_size=BATCH, seq_len=SEQ,
                        ordered=True, commit_policy="manual")
    a = TokenPipeline(make_log(cfg), pc)
    b = TokenPipeline(make_log(cfg), pc)
    assert [m.payload for m in a.next_docs(40)] == \
        [m.payload for m in b.next_docs(40)]  # pure function of the log
    # nothing committed yet: offsets only move on explicit commit
    assert all(v == 0 for v in a.offsets().values())
    a.commit({0: 3, 1: 2})
    assert a.offsets()[0] == 3 and a.offsets()[1] == 2
    # strict per-partition order: consumed offsets are contiguous ranges
    per_part = {}
    for m in b.next_docs(20):
        per_part.setdefault(m.partition, []).append(m.offset)
    for offsets in per_part.values():
        assert offsets == list(range(offsets[0], offsets[0] + len(offsets)))


def test_ordered_pipeline_replay_resumes_identically():
    """Rebuild at a committed point (offsets + rotation cursor): the
    replayed suffix is identical to the original stream."""
    cfg = get_arch("llama3.2-1b", smoke=True)
    pc = PipelineConfig(partitions=PARTS, batch_size=BATCH, seq_len=SEQ,
                        ordered=True, commit_policy="manual")
    a = TokenPipeline(make_log(cfg), pc)
    consumed = a.next_docs(8)
    offsets = {}
    for m in consumed:
        offsets[m.partition] = max(offsets.get(m.partition, -1), m.offset) + 1
    a.commit(offsets, rr=a.rotation_cursor())
    suffix = [m.payload for m in a.next_docs(12)]
    # the resume point pairs the committed offsets with the *committed*
    # rotation cursor even though the live cursor has prefetched past it
    state = a.stream_state()
    assert state["rr"] < a.rotation_cursor()

    c = TokenPipeline(make_log(cfg), pc)
    c.restore_stream_state(state)
    assert [m.payload for m in c.next_docs(12)] == suffix


# --- the training job ---------------------------------------------------------


def test_training_job_trains_and_accounts_exactly(rig):
    job = make_job(rig)
    final = job.run(10)
    assert final == 10
    assert all(np.isfinite(l) for l in job.losses)
    assert_exact_consumption(job, 10)
    assert job.counter("train.steps") == 10
    assert job.counter("train.tokens") == 10 * BATCH * (SEQ + 1)


def test_worker_chaos_kill_heals_bitwise_exact(rig):
    """ACCEPTANCE: uninterrupted vs kill-and-resume reach bitwise-
    identical params at the same step, with zero skipped and zero
    double-consumed batches."""
    golden = make_job(rig)
    golden.run(12)

    chaos = make_job(rig, heartbeat_timeout=2.0)
    now = 0.0
    while chaos.applied_step() < 3:
        chaos.step(now)
        now += 1.0
    chaos.kill_worker(0)
    final = chaos.run(12, now=now)
    assert final == 12
    assert chaos.counter("train.trainer_restarts") == 1
    assert any(e[1] == "restarted" for e in chaos.supervisor.events)
    assert_bitwise_equal(golden, chaos)
    assert chaos.committed_offsets() == golden.committed_offsets()
    assert chaos.step_offsets == golden.step_offsets
    assert_exact_consumption(chaos, 12)


def test_process_death_rebuilds_from_checkpoint_and_log(rig, tmp_path):
    """ACCEPTANCE (mirror of test_serving_log's full-process drill): kill
    the trainer mid-run, rebuild from checkpoint + token log alone, and
    the resumed run replays the uncommitted suffix to bitwise-identical
    final params with exactly-once token accounting."""
    cfg = rig[0]
    golden = make_job(rig)
    golden.run(12)

    d = str(tmp_path / "ckpt")
    j1 = make_job(rig, checkpoint_dir=d, checkpoint_every=3)
    now = 0.0
    while j1.applied_step() < 7:
        j1.step(now)
        now += 1.0
    died_at = j1.applied_step()
    assert 0 < died_at < 12, "kill must land mid-flight"
    first_life = dict(j1.step_offsets)
    del j1  # process death: the heap is GONE; store + regenerable log survive

    j2 = make_job(rig, log=make_log(cfg), checkpoint_dir=d,
                  checkpoint_every=3, resume=True)
    resumed_at = j2.applied_step()
    assert resumed_at <= died_at  # newest snapshot <= crash point
    assert resumed_at > 0, "must resume from a snapshot, not from scratch"
    j2.run(12)
    assert j2.applied_step() == 12
    assert_bitwise_equal(golden, j2)
    assert j2.committed_offsets() == golden.committed_offsets()
    # replayed steps consumed the identical offsets in both lives — the
    # at-least-once replay re-derived the same consumption, so across
    # the logical trajectory nothing was skipped or double-consumed
    for step, offs in j2.step_offsets.items():
        assert golden.step_offsets[step] == offs
        if step in first_life and step <= died_at:
            assert first_life[step] == offs
    # the durable journal spans both lives: replayed steps journaled the
    # identical consumption, and the whole trajectory is gap-free
    assert_exact_consumption(j2, 12, journaled_step_offsets(j2))


def test_resume_from_runahead_snapshot_stays_exact(rig, tmp_path):
    """Regression: a snapshot taken while assembly had prefetched past
    the committed step must record the rotation cursor of the *committed*
    point, not the live one — otherwise the resumed run replays the
    suffix in a different rotation phase and silently diverges."""
    cfg = rig[0]
    golden = make_job(rig)
    golden.run(12)

    d = str(tmp_path / "ckpt")
    # shard_budget=1 throttles the workers so assembly prefetch stays
    # ahead of the barrier when the step-3 snapshot lands
    j1 = make_job(rig, checkpoint_dir=d, checkpoint_every=3,
                  max_inflight_steps=3, shard_budget=1)
    now = 0.0
    runahead_at_snapshot = 0
    while j1.applied_step() < 4:
        j1.step(now)
        if j1.applied_step() == 3 and not runahead_at_snapshot:
            runahead_at_snapshot = j1._assembled - j1.applied_step()
        now += 1.0
    assert runahead_at_snapshot > 0, "snapshot must land mid-prefetch"
    del j1

    j2 = make_job(rig, log=make_log(cfg), checkpoint_dir=d,
                  checkpoint_every=3, max_inflight_steps=3, resume=True)
    assert j2.applied_step() == 3
    j2.run(12)
    assert_bitwise_equal(golden, j2)
    assert j2.committed_offsets() == golden.committed_offsets()
    for step, offs in j2.step_offsets.items():
        assert golden.step_offsets[step] == offs


def test_manual_rescale_2_4_3_is_a_live_event_and_batch_invariant(rig):
    """DP 2 -> 4 -> 3 mid-run through the on_scale actuation path: the
    worker set moves, the stream position is exact, and — because batch
    assembly is DP-degree-independent — params stay bitwise identical to
    a fixed-degree run."""
    golden = make_job(rig)
    golden.run(12)

    job = make_job(rig)
    now = 0.0
    while job.applied_step() < 4:
        job.step(now)
        now += 1.0
    job.request_scale(4)
    assert len(job.pool.active_workers()) == 4
    while job.applied_step() < 8:
        job.step(now)
        now += 1.0
    job.request_scale(3)
    assert len(job.pool.active_workers()) == 3
    job.run(12, now=now)
    assert [(o, n) for (_, o, n, _) in job.scale_log] == [(2, 4), (4, 3)]
    assert job.counter("train.rescales") == 2
    assert_bitwise_equal(golden, job)
    assert job.committed_offsets() == golden.committed_offsets()
    assert_exact_consumption(job, 12)


def test_autoscaler_scales_dp_out_on_stream_backlog(rig):
    """The queue-depth autoscaler (fed stream lag as rejected demand)
    scales DP out as a live pool event; training completes with exact
    consumption at the larger degree."""
    cfg = rig[0]
    job = make_job(
        rig, log=make_log(cfg, num_docs=120), dp=1, elastic=True,
        autoscaler=AutoscalerConfig(
            min_workers=1, max_workers=4, high_watermark=2.0,
            low_watermark=0.1, cooldown=2.0, step_fraction=1.0,
        ),
    )
    final = job.run(30)
    assert final == 30
    peak_dp = max(new for (_, _, new, _) in job.scale_log)
    assert peak_dp > 1, "backlog must have scaled DP out"
    assert job.counter("train.scale_out") >= 1
    assert len(job.pool.controller.scale_events) >= 1
    # ...and the pool scaled back in once the stream drained
    assert job.dp < peak_dp
    assert_exact_consumption(job, 30)


def test_retired_workers_never_lose_shards(rig):
    """Scale-in mid-flight redistributes queued shard messages to the
    survivors (overflow-safe drain) — every step still fires."""
    job = make_job(rig, dp=4, max_inflight_steps=4, shard_budget=1)
    now = 0.0
    for _ in range(2):
        job.step(now)
        now += 1.0
    job.request_scale(1)
    assert len(job.pool.active_workers()) == 1
    final = job.run(10, now=now)
    assert final == 10
    assert_exact_consumption(job, 10)


def test_training_as_terminal_stage_of_a_dataflow_graph(rig):
    """ISSUE 4: the token-ingestion front half is a dataflow stage — a
    preprocessing stage feeds the tokens topic through a StageGraph, the
    graph clock drives training, and two identical graph runs reach
    bitwise-identical params with exact consumption accounting (stage
    placement is provenance-keyed, so the document sequence is a pure
    function of the inputs, not of scheduling)."""
    from repro.core.dataflow import Stage, StageGraph
    from repro.data.sources import TokenSource
    from repro.data.topics import MessageLog

    cfg, tcfg, model, step_fn = rig

    def run_graph():
        log = MessageLog()
        log.create_topic("raw", 2)
        log.create_topic("tokens", PARTS)
        src = TokenSource(vocab_size=cfg.vocab_size, doc_len=SEQ + 1, seed=0)
        for key, doc in src.stream(DOCS):
            log.publish("raw", payload=doc, key=key)
        graph = StageGraph(log)
        graph.add(Stage("tokenize", log, "raw", "tokens",
                        process=lambda m: [m.payload],
                        initial_tasks=1, elastic=False))
        job = TrainingJob(model, cfg, tcfg, log, batch_size=BATCH,
                          seq_len=SEQ, dp=2, max_dp=4, train_step_fn=step_fn)
        graph.add(job.as_stage())
        assert graph.downstream(graph.stage("tokenize")) == [job.stage]
        graph.run_to_completion(max_rounds=2000)
        return job, graph

    job_a, graph_a = run_graph()
    job_b, _ = run_graph()
    assert job_a.applied_step() == DOCS // BATCH
    assert sum(job_a.committed_offsets().values()) == DOCS
    assert job_a.losses == job_b.losses
    assert_bitwise_equal(job_a, job_b)
    assert_exact_consumption(job_a, job_a.applied_step())
    # the preprocessing stage fully committed its own input too
    tk = graph_a.stage("tokenize")
    for c in tk.consumers.consumers:
        assert c.offset == tk.in_topic.partitions[c.partition].end_offset()


# --- async checkpointing + live handoff (ISSUE 8 tentpole) --------------------


def test_async_checkpoint_matches_sync_bitwise(rig, tmp_path):
    """The write-behind path is a pure latency optimization: an
    uninterrupted async+sharded run lands on the same params, losses,
    committed offsets, and per-step consumption as a plain run — and
    never takes a synchronous save."""
    golden = make_job(rig)
    golden.run(12)

    job = make_job(rig, checkpoint_dir=str(tmp_path / "a"),
                   checkpoint_every=3, async_checkpoint=True, ckpt_shards=2)
    job.run(12)
    assert job.store.sync_saves == 0 and job.store.async_saves > 0
    assert_bitwise_equal(golden, job)
    assert job.committed_offsets() == golden.committed_offsets()
    assert job.step_offsets == golden.step_offsets
    assert_exact_consumption(job, 12)


def test_async_process_death_resumes_bitwise(rig, tmp_path):
    """Process death with snapshots and journal lines still queued in
    the write-behind worker: the rebuilt job resumes from whatever
    actually landed and replays the rest to bitwise-identical params.
    The commit gate guarantees no offset ever committed ahead of its
    journal line, so the replay window always covers the loss."""
    cfg = rig[0]
    golden = make_job(rig)
    golden.run(12)

    d = str(tmp_path / "ckpt")
    j1 = make_job(rig, checkpoint_dir=d, checkpoint_every=3,
                  async_checkpoint=True, ckpt_shards=2)
    now = 0.0
    while j1.applied_step() < 7:
        j1.step(now)
        now += 1.0
    died_at = j1.applied_step()
    j1.kill_process()  # queued write-behind work is discarded, not flushed
    del j1

    j2 = make_job(rig, log=make_log(cfg), checkpoint_dir=d,
                  checkpoint_every=3, async_checkpoint=True, ckpt_shards=2,
                  resume=True)
    assert j2.resume_source == "snapshot"
    assert j2.applied_step() <= died_at
    j2.run(12)
    assert j2.applied_step() == 12
    assert_bitwise_equal(golden, j2)
    assert j2.committed_offsets() == golden.committed_offsets()
    for step, offs in j2.step_offsets.items():
        assert golden.step_offsets[step] == offs
    assert_exact_consumption(j2, 12, journaled_step_offsets(j2))


def test_commit_gate_holds_offsets_until_journal_durable(rig, tmp_path):
    """Commit-after-journal, asynchronously: while the write-behind
    worker is stalled, applied steps accumulate in the commit gate and
    their offsets do NOT commit; the gate also backpressures assembly
    instead of growing the uncommitted suffix unboundedly.  Resuming the
    worker drains the gate and commits exactly the applied prefix."""
    job = make_job(rig, checkpoint_dir=str(tmp_path / "g"),
                   checkpoint_every=100, async_checkpoint=True,
                   commit_gate_cap=2)
    now = 0.0
    while job.applied_step() < 2:
        job.step(now)
        now += 1.0
    job.flush_durability(now)
    committed_before = dict(job.committed_offsets())
    job.store.writer.pause()
    for _ in range(20):
        job.step(now)
        now += 1.0
    assert job.applied_step() > 2
    # nothing committed past the durable prefix...
    assert job.committed_offsets() == committed_before
    assert len(job._pending_commits) > 0
    # ...and the gate bounded how far the job ran ahead of durability
    assert len(job._pending_commits) <= job.commit_gate_cap + \
        job.max_inflight_steps + 1
    job.store.writer.resume()
    job.flush_durability(now)
    assert not job._pending_commits
    assert sum(job.committed_offsets().values()) == job.applied_step() * BATCH
    job.run(12, now=now)
    assert_exact_consumption(job, 12)


def test_remesh_with_handoff_takes_no_sync_save(rig, tmp_path):
    """The elastic move off the critical path: a 2->4 remesh with the
    async store publishes the state through the handoff topic and
    submits the safety snapshot to the write-behind worker — zero
    synchronous saves anywhere — and stays bitwise-identical to a
    fixed-degree run."""
    from repro.checkpoint.handoff import StateHandoffChannel

    cfg = rig[0]
    golden = make_job(rig)
    golden.run(12)

    log = make_log(cfg)
    job = make_job(rig, log=log, checkpoint_dir=str(tmp_path / "h"),
                   checkpoint_every=5, async_checkpoint=True, ckpt_shards=2,
                   handoff=StateHandoffChannel(log, shards=2))
    now = 0.0
    while job.applied_step() < 4:
        job.step(now)
        now += 1.0
    job.request_scale(4)
    job.run(12, now=now)
    assert job.store.sync_saves == 0
    assert job.handoff.states_published >= 1  # the remesh publish
    assert [(o, n) for (_, o, n, _) in job.scale_log] == [(2, 4)]
    assert_bitwise_equal(golden, job)
    assert job.committed_offsets() == golden.committed_offsets()
    assert_exact_consumption(job, 12)


def test_handoff_resume_is_last_delta_catchup(rig, tmp_path):
    """With per-step handoff publishes, a killed process's replacement
    resumes from the exact handoff step (not the last periodic
    snapshot): resume_source == 'handoff' and zero-or-tiny replay."""
    from repro.checkpoint.handoff import StateHandoffChannel

    cfg = rig[0]
    golden = make_job(rig)
    golden.run(12)

    log = make_log(cfg)  # the durable broker survives the process
    d = str(tmp_path / "hh")
    j1 = make_job(rig, log=log, checkpoint_dir=d, checkpoint_every=5,
                  async_checkpoint=True, ckpt_shards=2,
                  handoff=StateHandoffChannel(log, shards=2),
                  handoff_every=1)
    now = 0.0
    while j1.applied_step() < 8:
        j1.step(now)
        now += 1.0
    died_at = j1.applied_step()
    j1.kill_process()
    del j1

    j2 = make_job(rig, log=log, checkpoint_dir=d, checkpoint_every=5,
                  async_checkpoint=True, ckpt_shards=2,
                  handoff=StateHandoffChannel(log, shards=2),
                  handoff_every=1, resume=True)
    assert j2.resume_source == "handoff"
    assert j2.applied_step() == died_at  # no replay gap at all
    assert j2.handoff_deltas_applied == 0
    j2.run(12, now=now)
    assert_bitwise_equal(golden, j2)
    assert j2.committed_offsets() == golden.committed_offsets()
    for step, offs in j2.step_offsets.items():
        assert golden.step_offsets[step] == offs

"""Sharding: rule divisibility guarantees (pure), plus a reduced-mesh
lower+compile in a subprocess (the only place tests may fake devices —
conftest must keep the main process at 1 device)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.config import get_arch

pytestmark = pytest.mark.slow  # heavy sweep/compile module: excluded from tier-1

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape


@pytest.mark.parametrize("arch", [
    "gemma3-4b", "minicpm-2b", "llama3.2-1b", "command-r-plus-104b",
    "mixtral-8x7b", "llama4-maverick-400b-a17b", "internvl2-1b",
    "jamba-v0.1-52b", "whisper-tiny", "mamba2-370m",
])
def test_rules_are_divisible_for_production_mesh(arch):
    """Every rule the builder leaves enabled must divide the dimension it
    shards — pjit rejects anything else."""
    from repro.distributed.param_shardings import make_rules

    cfg = get_arch(arch)
    mesh = _FakeMesh({"data": 16, "model": 16})
    rules = make_rules(cfg, mesh)
    model = 16

    def size_of(axis):
        return {"data": 16, "model": 16}.get(axis, 1)

    if rules["heads"]:
        assert cfg.num_heads % size_of(rules["heads"]) == 0
    if rules["kv_heads"]:
        assert cfg.num_kv_heads % size_of(rules["kv_heads"]) == 0
    if rules["head_dim"]:
        assert cfg.resolved_head_dim % size_of(rules["head_dim"]) == 0
    if rules["ffn"]:
        assert cfg.d_ff % size_of(rules["ffn"]) == 0
    if rules["vocab"]:
        assert cfg.vocab_size % size_of(rules["vocab"]) == 0
    if rules["embed_fsdp"]:
        assert cfg.d_model % size_of(rules["embed_fsdp"]) == 0
    if cfg.moe and rules["expert"]:
        assert cfg.moe.num_experts % size_of(rules["expert"]) == 0


def test_long_context_rules_swap_batch_for_kv_seq():
    from repro.distributed.param_shardings import make_rules

    cfg = get_arch("mamba2-370m")
    mesh = _FakeMesh({"data": 16, "model": 16})
    rules = make_rules(cfg, mesh, long_context=True)
    assert rules["kv_seq"] == "data"
    assert rules["batch"] in (None, ())


_SUBPROCESS_PROGRAM = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    from repro.launch.dryrun import run_cell
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    out = {}
    for arch, shape in [("llama3.2-1b", "train_4k"),
                        ("mixtral-8x7b", "decode_32k"),
                        ("mamba2-370m", "long_500k")]:
        res = run_cell(arch, shape, mesh, "4x2-test")
        out[f"{arch}/{shape}"] = {
            "status": res["status"],
            "collective": res.get("collective_bytes_per_chip", 0),
            "dominant": res.get("dominant"),
        }
    print("RESULT " + json.dumps(out))
""")


def test_reduced_mesh_lower_and_compile():
    """Real pjit lower+compile on an 8-device host mesh (subprocess so the
    main test process keeps a single device)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_PROGRAM],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    for cell, res in out.items():
        assert res["status"] == "ok", (cell, res)
        assert res["collective"] > 0, f"{cell}: sharded step must communicate"

"""Data pipeline (virtual-messaging-backed) + TCMM app + telemetry."""

import numpy as np
import pytest

from repro.apps.tcmm import MacroClusterJob, MicroClusterJob, MicroClusterState
from repro.configs.tcmm import TCMMConfig
from repro.core.liquid import LiquidJob
from repro.core.reactive import ReactiveJob
from repro.data.pipeline import PipelineConfig, TokenPipeline, build_token_log
from repro.data.sources import TokenSource, TrajectorySource
from repro.data.topics import MessageLog
from repro.telemetry.metrics import MetricsHub, MetricsReplica


# --- sources ------------------------------------------------------------------


def test_token_source_deterministic():
    src = TokenSource(vocab_size=256, doc_len=64, seed=3)
    a, b = src.doc(17), src.doc(17)
    np.testing.assert_array_equal(a, b)
    assert a.min() >= 0 and a.max() < 256
    assert not np.array_equal(src.doc(17), src.doc(18))


def test_trajectory_source_keys_and_features():
    src = TrajectorySource(num_taxis=10, seed=1)
    pts = list(src.stream(50))
    assert len(pts) == 50
    keys = {k for k, _ in pts}
    assert len(keys) == 10
    assert all(len(v) == 4 for _, v in pts)


# --- pipeline ------------------------------------------------------------------


def test_pipeline_more_queues_than_partitions():
    """The paper's point on the data path: 2 partitions feed 8 queues."""
    log = build_token_log(vocab_size=128, num_docs=64, doc_len=33,
                          partitions=2, seed=0)
    pipe = TokenPipeline(log, PipelineConfig(
        partitions=2, num_queues=8, batch_size=4, seq_len=16))
    batches = list(pipe)
    assert len(batches) >= 20
    for b in batches:
        assert b["tokens"].shape == (4, 16)
        assert b["labels"].shape == (4, 16)
        # next-token alignment within the packed stream
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_pipeline_state_dict_checkpoint_resume():
    """Restoring the pipeline state (offsets + in-flight messages + carry)
    resumes the stream bit-exactly."""
    make = lambda: TokenPipeline(
        build_token_log(vocab_size=64, num_docs=40, doc_len=65, partitions=4),
        PipelineConfig(partitions=4, num_queues=4, batch_size=2, seq_len=32),
    )
    p1 = make()
    first = [p1.next_batch() for _ in range(3)]
    saved = p1.state_dict()
    after_save = [p1.next_batch() for _ in range(3)]

    p2 = make()
    p2.load_state_dict(saved)
    resumed = [p2.next_batch() for _ in range(3)]
    for a, b in zip(after_save, resumed):
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        np.testing.assert_array_equal(a["labels"], b["labels"])


# --- tcmm ------------------------------------------------------------------------


def test_micro_clustering_converges_on_blobs():
    cfg = TCMMConfig(max_micro_clusters=64, distance_threshold=3.0, feature_dim=2)
    rng = np.random.default_rng(0)
    centers = np.asarray([[0.0, 0.0], [20.0, 0.0], [0.0, 20.0]])
    state = MicroClusterState(cfg)
    for i in range(600):
        c = centers[i % 3]
        state.ingest((c + rng.normal(0, 0.5, 2)).astype(np.float32))
    assert 3 <= state.num_active <= 12  # a few micro-clusters per blob
    assert state.processed == 600


def test_micro_state_event_replay_equivalence():
    """Event sourcing: replaying the change log rebuilds the exact state."""
    cfg = TCMMConfig(max_micro_clusters=32, distance_threshold=2.0, feature_dim=2)
    rng = np.random.default_rng(1)
    state = MicroClusterState(cfg)
    events = [state.ingest(rng.normal(0, 5, 2).astype(np.float32))
              for _ in range(200)]
    rebuilt = MicroClusterState.replay(cfg, events)
    np.testing.assert_allclose(rebuilt.n, state.n)
    np.testing.assert_allclose(rebuilt.ls, state.ls, rtol=1e-6)
    assert rebuilt.num_active == state.num_active


def test_tcmm_two_stage_pipeline_on_reactive():
    """The paper's exact wiring: trajectories -> micro job -> changes topic
    -> macro job, on the Reactive Liquid stack."""
    cfg = TCMMConfig(max_micro_clusters=128, distance_threshold=4.0,
                     feature_dim=4, num_macro_clusters=4, macro_period=128)
    log = MessageLog()
    log.create_topic("trajectories", 3)
    log.create_topic("micro-changes", 3)
    src = TrajectorySource(num_taxis=30, seed=2)
    for key, point in src.stream(600):
        log.publish("trajectories", payload=point, key=key)

    micro = MicroClusterJob(cfg)
    macro = MacroClusterJob(cfg)
    micro_job = ReactiveJob("micro", log, "trajectories", micro,
                            out_topic="micro-changes", initial_tasks=1,
                            elastic=False)
    macro_job = ReactiveJob("macro", log, "micro-changes", macro,
                            initial_tasks=1, elastic=False)
    for r in range(2000):
        micro_job.step(now=float(r))
        macro_job.step(now=float(r))
        if micro_job.backlog() == 0 and macro_job.backlog() == 0:
            break
    assert micro.state.processed == 600
    assert macro.replica.processed == 600
    assert macro.macro_runs >= 1
    assert macro.macro_centers.shape == (4, 4)


def test_tcmm_on_liquid_baseline_same_results():
    """Liquid and Reactive produce identical micro-cluster state (the
    architecture changes throughput, not semantics). Single partition +
    single task pins the ingest order for strict equality; with multiple
    partitions the two stacks interleave differently (both valid TCMM
    orders)."""
    cfg = TCMMConfig(max_micro_clusters=64, distance_threshold=4.0, feature_dim=4)
    def run(job_cls, **kw):
        log = MessageLog()
        log.create_topic("t", 1)
        for key, p in TrajectorySource(num_taxis=10, seed=5).stream(200):
            log.publish("t", payload=p, key=key)
        micro = MicroClusterJob(cfg)
        job = job_cls("m", log, "t", micro, **kw)
        job.run_to_completion()
        return micro.state

    a = run(LiquidJob, num_tasks=1)
    b = run(ReactiveJob, initial_tasks=1, elastic=False)
    np.testing.assert_allclose(a.n, b.n)
    np.testing.assert_allclose(a.ls, b.ls, rtol=1e-6)


# --- telemetry -----------------------------------------------------------------


def test_metrics_merge_survives_restart():
    hub = MetricsHub()
    w1 = MetricsReplica("w1")
    w1.incr("messages", 10)
    hub.ingest(w1)
    hub.ingest(w1)  # duplicate ingest is idempotent (G-Counter max-merge)
    assert hub.counter("messages") == 10
    # worker restarts with empty replica, does more work
    w1b = MetricsReplica("w1")
    w1b.counters["messages"] = w1.counters["messages"]  # journal recovery
    w1b.incr("messages", 5)
    hub.ingest(w1b)
    assert hub.counter("messages") == 15


def test_metrics_gauges_lww():
    hub = MetricsHub()
    a, b = MetricsReplica("a"), MetricsReplica("b")
    a.gauge("loss", 3.5, timestamp=10.0)
    b.gauge("loss", 3.1, timestamp=11.0)
    hub.ingest(a)
    hub.ingest(b)
    assert hub.gauge("loss") == 3.1  # newest write wins

"""Supervision + elasticity unit tests (paper §2.2, §3.2.2)."""

import pytest

from repro.core.elastic import (
    AutoscalerConfig,
    QueueDepthAutoscaler,
    WorkerPoolController,
    detect_stragglers,
)
from repro.core.supervision import (
    HeartbeatDetector,
    PhiAccrualDetector,
    Supervisor,
)

# --- failure detectors ---------------------------------------------------------


def test_heartbeat_detector():
    d = HeartbeatDetector(timeout=5.0)
    assert not d.suspect(100.0)  # never beat: not suspect (not started)
    d.observe(100.0)
    assert not d.suspect(104.0)
    assert d.suspect(105.1)


def test_phi_accrual_grows_with_silence():
    d = PhiAccrualDetector(threshold=8.0)
    for t in range(20):  # steady 1s heartbeats
        d.observe(float(t))
    assert d.phi(19.5) < 1.0
    assert d.phi(20.5) < 8.0
    assert d.phi(40.0) > 8.0
    assert d.suspect(40.0)


def test_phi_adapts_to_jitter():
    """Jittery-but-alive links should not be declared dead too eagerly."""
    steady = PhiAccrualDetector()
    jittery = PhiAccrualDetector()
    for t in range(40):
        steady.observe(float(t))
    for t in range(0, 80, 2):  # 2s cadence with the same final beat time
        jittery.observe(float(t))
    probe = 82.0
    assert jittery.phi(probe) < steady.phi(probe)


# --- supervisor ------------------------------------------------------------------


def test_supervisor_restarts_silent_child():
    restarts = []
    sup = Supervisor()
    sup.supervise("w1", restart=lambda: restarts.append("w1"),
                  detector=HeartbeatDetector(3.0))
    sup.heartbeat("w1", 0.0)
    assert sup.check(1.0) == []
    assert sup.check(10.0) == ["w1"]
    assert restarts == ["w1"]
    # restart counted as a beat; no immediate re-restart
    assert sup.check(11.0) == []


def test_supervisor_gives_up_after_max_restarts():
    sup = Supervisor()
    sup.supervise("w1", restart=lambda: None,
                  detector=HeartbeatDetector(1.0), max_restarts=2)
    sup.heartbeat("w1", 0.0)
    t = 0.0
    restarted = 0
    for _ in range(5):
        t += 10.0
        restarted += len(sup.check(t))
    assert restarted == 2
    assert "w1" not in sup.alive_children()
    assert any(e[1] == "gave_up" for e in sup.events)


def test_supervisor_recovery_event_on_late_beat():
    sup = Supervisor()
    child = sup.supervise("w1", restart=lambda: None,
                          detector=HeartbeatDetector(1.0), max_restarts=0)
    sup.heartbeat("w1", 0.0)
    sup.check(10.0)
    assert not child.alive
    sup.heartbeat("w1", 11.0)
    assert child.alive
    assert any(e[1] == "recovered" for e in sup.events)


# --- autoscaler ---------------------------------------------------------------


def test_autoscaler_scales_out_on_backlog():
    a = QueueDepthAutoscaler(AutoscalerConfig(high_watermark=10, cooldown=0))
    d = a.decide([50, 60, 40], now=0.0)
    assert d.action == "scale_out"
    assert d.delta >= 1


def test_autoscaler_scales_in_when_idle():
    a = QueueDepthAutoscaler(
        AutoscalerConfig(low_watermark=2, min_workers=1, cooldown=0)
    )
    d = a.decide([0, 0, 1, 0], now=0.0)
    assert d.action == "scale_in"


def test_autoscaler_cooldown_and_bounds():
    cfg = AutoscalerConfig(high_watermark=1, cooldown=100, max_workers=4)
    a = QueueDepthAutoscaler(cfg)
    assert a.decide([100, 100], now=0.0).action == "scale_out"
    assert a.decide([100, 100], now=1.0).action == "hold"  # cooling down
    ctrl = WorkerPoolController(2, cfg)
    for t in (200.0, 400.0, 600.0):
        ctrl.observe([100] * ctrl.target_size, now=t)
    assert ctrl.target_size <= 4  # max bound respected


def test_straggler_detection_flags_slow_worker():
    rates = {f"w{i}": 100.0 for i in range(8)}
    rates["w7"] = 3.0
    report = detect_stragglers(rates, k=3.0)
    assert report.straggler_ids == ("w7",)


def test_straggler_detection_ignores_small_pools():
    assert detect_stragglers({"a": 1.0, "b": 100.0}).straggler_ids == ()


def test_straggler_detection_uniform_pool_clean():
    rates = {f"w{i}": 50.0 for i in range(10)}
    assert detect_stragglers(rates).straggler_ids == ()

"""The cluster/placement layer as a live reactive service: placement,
node-failure silencing, relocation, dilation, rebalancing — and the
hypothesis-checked invariants (residency conservation, down-node
quiescence, stale-epoch events never resurrecting anything)."""

import itertools

import pytest

from repro.core.cluster import (
    Cluster,
    FailureConfig,
    FailureInjector,
    StepCost,
)
from repro.core.pool import ElasticPool, WorkerBase
from repro.core.runtime import SimEngine
from repro.core.messages import Message
from tests._hypothesis_support import given, settings, st


class CountingWorker(WorkerBase):
    """Processes one mailbox message per step call."""

    _ids = itertools.count()

    def __init__(self, sink):
        super().__init__(f"cw{next(CountingWorker._ids)}")
        self.sink = sink

    def step(self, now: float = 0.0) -> int:
        msg = self.mailbox.get()
        if msg is None:
            return 0
        self.sink.append(msg.payload)
        self.metrics.incr("task.processed")
        return 1


def make_pool(cluster, n=4, sink=None, **kw):
    sink = sink if sink is not None else []
    pool = ElasticPool(
        "placed",
        lambda: CountingWorker(sink),
        initial_units=n,
        elastic=False,
        heartbeat_timeout=2.0,
        cluster=cluster,
        restart_cost=kw.pop("restart_cost", 1.0),
        **kw,
    )
    return pool, sink


def feed(pool, n, start=0):
    for i in range(start, start + n):
        pool.route(Message(topic="t", payload=i))


# --- placement basics ---------------------------------------------------------


def test_spawn_places_least_loaded_and_registers_residency():
    cluster = Cluster(3, cores=2)
    pool, _ = make_pool(cluster, n=6)
    assert all(w.node is not None for w in pool.workers)
    counts = sorted(len(n.residents) for n in cluster.nodes)
    assert counts == [2, 2, 2]
    assert cluster.total_residents() == 6
    names = {w.name for w in pool.workers}
    for node in cluster.nodes:
        assert node.residents <= names


def test_residency_index_is_source_of_truth():
    """assign/release/node_of ride the name->node index (no scans):
    re-assign moves exactly one residency, release drops it, and the
    audit (the demoted scan) agrees after every mutation."""
    cluster = Cluster(4, cores=2)
    a, b = cluster.nodes[0], cluster.nodes[1]
    cluster.assign(a, "x")
    cluster.assign(a, "x")  # idempotent
    assert cluster.node_of("x") is a and cluster.total_residents() == 1
    cluster.assign(b, "x")  # moves, never duplicates
    assert cluster.node_of("x") is b
    assert "x" not in a.residents and "x" in b.residents
    assert cluster.total_residents() == 1
    cluster.audit()
    cluster.release("x")
    assert cluster.node_of("x") is None and cluster.total_residents() == 0
    cluster.release("x")  # releasing a stranger is a no-op
    cluster.audit()


def test_dilation_cache_invalidated_on_residency_and_speed_change():
    cluster = Cluster(1, cores=2)
    node = cluster.nodes[0]
    assert node.dilation() == 1.0
    for i in range(4):
        cluster.assign(node, f"w{i}")
    assert node.dilation() == 2.0          # 4 residents / 2 cores
    cluster.release("w0")
    assert node.dilation() == 1.5
    cluster.set_speed(node, 0.5)
    assert node.dilation() == 3.0
    cluster.audit()


def test_node_down_silences_all_residents_and_supervisor_relocates():
    cluster = Cluster(3, cores=2)
    pool, sink = make_pool(cluster, n=6)
    feed(pool, 60)
    victim_node = cluster.nodes[0]
    silenced = set(victim_node.residents)
    assert len(silenced) == 2
    cluster.fail(victim_node)
    now = 0.0
    for _ in range(8):  # past the 2.0 heartbeat timeout
        pool.step(now)
        now += 1.0
    # every worker that lived on the dead node was relocated to a live one
    assert all(
        w.node is not None and w.node.up and w.node is not victim_node
        for w in pool.workers
    )
    assert not victim_node.residents
    assert cluster.total_residents() == 6
    # nothing lost: re-admitted messages drain through the survivors
    for _ in range(80):
        pool.step(now)
        now += 1.0
    assert sorted(sink) == sorted(range(60))


def test_restart_cost_delays_relocated_worker():
    cluster = Cluster(2, cores=4)
    pool, sink = make_pool(cluster, n=2, restart_cost=5.0)
    feed(pool, 4)
    cluster.fail(cluster.nodes[0])
    now = 0.0
    for _ in range(4):
        pool.step(now)
        now += 1.0
    # relocation happened (heartbeat timeout 2.0) but the fresh worker is
    # still warming: it must not have processed anything yet
    relocated = [w for w in pool.workers if getattr(w, "warm_until", 0) > 0]
    assert relocated
    warm_until = max(w.warm_until for w in relocated)
    assert warm_until > now - 1.0
    processed_before = len(sink)
    while now < warm_until + 3.0:
        pool.step(now)
        now += 1.0
    assert len(sink) > processed_before or len(sink) == 4
    assert sorted(sink) == sorted(range(4))


def test_rebalance_moves_workers_onto_recovered_node():
    cluster = Cluster(2, cores=2)
    pool, _ = make_pool(cluster, n=4)
    dead = cluster.nodes[0]
    cluster.fail(dead)
    now = 0.0
    for _ in range(6):
        pool.step(now)
        now += 1.0
    assert len(cluster.nodes[1].residents) == 4  # everyone crowded on node 1
    cluster.restore(dead)
    for _ in range(10):
        pool.step(now)
        now += 1.0
    counts = sorted(len(n.residents) for n in cluster.nodes)
    assert counts == [2, 2], "recovered capacity stayed idle"


def test_dilation_is_physical():
    """N workers on c cores process at most c messages per round."""
    cluster = Cluster(1, cores=2)
    pool, sink = make_pool(cluster, n=6, restart_cost=0.0)
    feed(pool, 120)
    per_round = []
    for r in range(40):
        before = len(sink)
        pool.step(float(r))
        per_round.append(len(sink) - before)
    # dilation = 6 residents / 2 cores = 3 -> each worker steps 1/3 of
    # rounds -> ~2 messages per round (the 2-core budget), so 40 rounds
    # drain ~80 of the 120 — never more than the cores allow
    assert 72 <= len(sink) <= 84
    # capacity holds over any 6-round window (credit phases align, so a
    # single round may burst, but the window average is the core budget)
    for i in range(0, 36, 6):
        assert sum(per_round[i:i + 6]) <= 2 * 6 + 2
    # and nothing is lost once given enough rounds
    for r in range(40, 120):
        pool.step(float(r))
    assert sorted(sink) == sorted(range(120))
    # a straggler node (speed 0.5) halves the rate again: dilation 6
    slow = Cluster(1, cores=2, speeds=[0.5])
    pool2, sink2 = make_pool(slow, n=6, restart_cost=0.0)
    feed(pool2, 120)
    for r in range(40):
        pool2.step(float(r))
    assert 36 <= len(sink2) <= 44  # ~1 msg/round


def test_cost_metering_converts_time_to_budget():
    cluster = Cluster(1, cores=4)
    pool, sink = make_pool(
        cluster, n=2, restart_cost=0.0, step_cost=StepCost(t_process0=0.1)
    )
    feed(pool, 200)
    # 10 rounds of dt=0.5 -> 5 s of virtual time -> 2 workers each
    # process 5.0 / 0.1 = 50 messages: 100 of the 200, not more
    now = 0.0
    for _ in range(10):
        now += 0.5
        pool.step(now)
    assert len(sink) == pytest.approx(100, abs=4)


def test_failure_injector_epoch_guard_blocks_stale_restore():
    engine = SimEngine()
    cluster = Cluster(2, cores=2)
    node = cluster.nodes[0]
    e1 = cluster.fail(node)
    # node fails AGAIN (manual chaos) before the scheduled restore fires
    cluster.restore(node, e1)
    e2 = cluster.fail(node)
    assert not cluster.restore(node, e1), "stale restore resurrected the node"
    assert not node.up
    assert cluster.restore(node, e2)
    assert node.up


def test_failure_injector_rides_the_engine():
    engine = SimEngine()
    cluster = Cluster(3, cores=2)
    inj = FailureInjector(
        engine, cluster,
        FailureConfig(probability=1.0, interval=10.0, restart_delay=4.0, seed=1),
    )
    engine.run_until(11.0)
    assert inj.failures == 3 and not cluster.healthy()
    engine.run_until(15.0)
    assert len(cluster.healthy()) == 3
    assert inj.restores == 3


def test_whole_cluster_down_then_recovery():
    """With every node down nothing steps, nothing is lost, and the pool
    adopts the first node that comes back."""
    cluster = Cluster(2, cores=4)
    pool, sink = make_pool(cluster, n=3, restart_cost=1.0)
    feed(pool, 30)
    for node in cluster.nodes:
        cluster.fail(node)
    now = 0.0
    for _ in range(10):
        pool.step(now)
        now += 1.0
    assert len(sink) == 0
    cluster.restore(cluster.nodes[1])
    for _ in range(40):
        pool.step(now)
        now += 1.0
    assert sorted(sink) == sorted(range(30))
    assert cluster.total_residents() == 3


# --- hypothesis property: invariants under arbitrary chaos sequences ----------


@settings(max_examples=40, deadline=None)
@given(
    ops=st.lists(
        st.one_of(
            st.tuples(st.just("fail"), st.integers(0, 2)),
            st.tuples(st.just("restore"), st.integers(0, 2)),
            st.tuples(st.just("kill"), st.integers(0, 5)),
            st.tuples(st.just("scale"), st.integers(1, 8)),
            st.tuples(st.just("step"), st.integers(1, 4)),
        ),
        min_size=1,
        max_size=30,
    )
)
def test_cluster_invariants_under_chaos(ops):
    """Across arbitrary fail/restore/kill/scale/step sequences:

    * residency conservation — every pool worker is resident on exactly
      one node (or unplaced only while the whole cluster is down);
    * stale epochs never resurrect — a restore carrying an old epoch is
      a no-op;
    * down-node quiescence — after a full detection window with a
      healthy node available, no *live* worker remains on a down node.
    """
    cluster = Cluster(3, cores=2)
    pool, _ = make_pool(cluster, n=4, restart_cost=1.0)
    now = 0.0
    tokens = {}  # node_id -> epoch token of its OLDEST failure (may go stale)
    for op, arg in ops:
        if op == "fail":
            node = cluster.nodes[arg]
            if node.up:
                tokens.setdefault(arg, cluster.fail(node))
        elif op == "restore":
            node = cluster.nodes[arg]
            token = tokens.pop(arg, None)
            if token is not None:
                was_down, cur_epoch = not node.up, node.epoch
                ok = cluster.restore(node, token)
                # a restore succeeds iff the node is down AND the token
                # is from its *latest* failure; a stale token (the node
                # failed again since) must resurrect nothing
                assert ok == (was_down and token == cur_epoch)
                if not ok and was_down:
                    assert not node.up
        elif op == "kill" and pool.workers:
            pool.kill_worker(arg % len(pool.workers))
        elif op == "scale":
            pool.set_target_units(arg)
        elif op == "step":
            for _ in range(arg):
                pool.step(now)
                now += 1.0

        # Invariant: residency conservation, continuously — including
        # the index-vs-scan agreement the residency index must keep
        # (the old O(N) scans live on as this debug assertion).
        cluster.audit()
        placed = [w for w in pool.workers if getattr(w, "node", None) is not None]
        assert cluster.total_residents() == len(placed)
        for w in placed:
            assert w.name in w.node.residents
            owners = [n for n in cluster.nodes if w.name in n.residents]
            assert owners == [w.node]
            assert cluster.node_of(w.name) is w.node
        # unplaced workers are only possible with zero healthy nodes at
        # their (re)placement attempt; if any node is healthy the
        # rebalance pass re-places them within a step, checked below.

    # Quiesce: run past the detection window with everything healthy.
    for node in cluster.nodes:
        cluster.restore(node)
    for _ in range(8):
        pool.step(now)
        now += 1.0
    for w in pool.workers:
        assert w.node is not None and w.node.up
    assert cluster.total_residents() == len(pool.workers)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), p=st.floats(0.1, 1.0))
def test_injector_restores_everything_it_fails(seed, p):
    engine = SimEngine()
    cluster = Cluster(3, cores=2)
    inj = FailureInjector(
        engine, cluster,
        FailureConfig(probability=p, interval=5.0, restart_delay=2.0, seed=seed),
    )
    engine.run_until(103.0)  # past the last restart
    assert len(cluster.healthy()) == 3
    assert inj.restores == inj.failures

"""Roofline analysis unit tests: HLO collective parsing, term math,
rule-builder divisibility guarantees."""

import jax
import numpy as np
import pytest

from repro.config import get_arch
from repro.roofline.analysis import (
    HW_V5E,
    RooflineReport,
    collective_bytes_from_hlo,
    model_flops,
)

HLO_SAMPLE = """
HloModule test

ENTRY %main {
  %p0 = bf16[128,256]{1,0} parameter(0)
  %ag = bf16[2048,256]{1,0} all-gather(bf16[128,256]{1,0} %p0), dimensions={0}
  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %x), to_apply=%add
  %rs = bf16[64,256]{1,0} reduce-scatter(bf16[1024,256]{1,0} %y), dimensions={0}
  %a2a = bf16[8,32,64]{2,1,0} all-to-all(bf16[8,32,64]{2,1,0} %z), dimensions={0}
  %cp-start = bf16[16,16]{1,0} collective-permute-start(bf16[16,16]{1,0} %w)
  %cp-done = bf16[16,16]{1,0} collective-permute-done(%cp-start)
  %dot = f32[128,128]{1,0} dot(%a, %b)
}
"""


def test_collective_parsing_kinds_and_bytes():
    out = collective_bytes_from_hlo(HLO_SAMPLE)
    assert out["all-gather"] == 2048 * 256 * 2
    assert out["all-reduce"] == 1024 * 4
    assert out["reduce-scatter"] == 64 * 256 * 2
    assert out["all-to-all"] == 8 * 32 * 64 * 2
    # async pair counted exactly once (the -start side)
    assert out["collective-permute"] == 16 * 16 * 2


def test_collective_parsing_ignores_compute_ops():
    out = collective_bytes_from_hlo("%d = f32[4096,4096]{1,0} dot(%a, %b)")
    assert sum(out.values()) == 0


def test_roofline_terms_and_dominance():
    r = RooflineReport(
        arch="x", shape="train_4k", mesh="1x16x16", chips=256,
        flops_per_chip=197e12 * 0.5,          # 0.5 s of compute
        hbm_bytes_per_chip=819e9 * 0.1,       # 0.1 s of memory
        collective_bytes_per_chip=int(50e9 * 0.2),  # 0.2 s of collectives
        collective_breakdown={},
        model_flops_global=197e12 * 256 * 0.25,  # 0.25 s of useful work
    )
    assert r.t_compute == pytest.approx(0.5)
    assert r.t_memory == pytest.approx(0.1)
    assert r.t_collective == pytest.approx(0.2)
    assert r.dominant == "compute"
    assert r.roofline_fraction == pytest.approx(0.5)   # 0.25 / 0.5
    assert r.useful_flops_fraction == pytest.approx(0.5)


def test_model_flops_train_vs_infer():
    assert model_flops(1_000_000, 100, "train") == 6e8
    assert model_flops(1_000_000, 100, "infer") == 2e8


def test_param_count_formulas():
    """6*N*D consistency: the MoE active count strictly below total."""
    moe = get_arch("mixtral-8x7b")
    assert moe.active_param_count() < moe.param_count()
    dense = get_arch("llama3.2-1b")
    assert dense.active_param_count() == dense.param_count()

"""Event-sourced checkpoint store: atomicity, restore, journal replay,
corruption fallback, GC."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import CheckpointStore, load_pytree, save_pytree


def tree(seed=0):
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 3)
    return {
        "a": jax.random.normal(ks[0], (4, 8)),
        "nested": {"b": jax.random.normal(ks[1], (3,), dtype=jnp.bfloat16),
                   "c": jnp.asarray(7, dtype=jnp.int32)},
    }


def assert_tree_equal(x, y):
    for a, b in zip(jax.tree.leaves(x), jax.tree.leaves(y)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pytree_roundtrip(tmp_path):
    t = tree()
    path = str(tmp_path / "x.ckpt")
    save_pytree(t, path, meta={"step": 3})
    loaded, meta = load_pytree(t, path)
    assert meta["step"] == 3
    assert_tree_equal(t, loaded)
    assert loaded["nested"]["b"].dtype == jnp.bfloat16


def test_codec_tagged_roundtrip_and_fallback(tmp_path):
    """Snapshots are codec-tagged: zlib files load regardless of whether
    zstandard is installed, and asking for zstd without the lib is a clear
    error instead of a corrupt file."""
    from repro.checkpoint.store import default_codec, zstd

    t = tree()
    p_zlib = str(tmp_path / "zl.ckpt")
    save_pytree(t, p_zlib, meta={"codec": "zlib"}, codec="zlib")
    loaded, meta = load_pytree(t, p_zlib)
    assert meta["codec"] == "zlib"
    assert_tree_equal(t, loaded)
    with open(p_zlib, "rb") as fh:
        assert fh.read(4) == b"RLZL"
    if zstd is not None:
        p_zstd = str(tmp_path / "zs.ckpt")
        save_pytree(t, p_zstd, meta={}, codec="zstd")
        loaded, _ = load_pytree(t, p_zstd)
        assert_tree_equal(t, loaded)
        assert default_codec() == "zstd"
    else:
        assert default_codec() == "zlib"
        with pytest.raises(RuntimeError):
            save_pytree(t, str(tmp_path / "zs.ckpt"), codec="zstd")
    with pytest.raises(ValueError):
        save_pytree(t, str(tmp_path / "x.ckpt"), codec="lz4")


def test_store_restore_latest_with_journal(tmp_path):
    store = CheckpointStore(str(tmp_path))
    t0, t1 = tree(0), tree(1)
    store.save(t0, step=10, offsets={0: 100, 1: 90})
    store.record_step(11, offsets={0: 110, 1: 95}, metrics={"loss": 3.2})
    store.save(t1, step=12, offsets={0: 120, 1: 100})
    store.record_step(13, offsets={0: 130, 1: 105}, metrics={"loss": 3.0})
    state, meta, events = store.restore_latest(t0)
    assert meta["step"] == 12
    assert_tree_equal(state, t1)
    assert [e.data["step"] for e in events] == [13]
    assert store.latest_offsets() == {0: 130, 1: 105}


def test_corrupt_snapshot_falls_back(tmp_path):
    store = CheckpointStore(str(tmp_path))
    t0, t1 = tree(0), tree(1)
    store.save(t0, step=1)
    p2 = store.save(t1, step=2)
    with open(p2, "wb") as fh:
        fh.write(b"garbage")  # simulate a torn write
    state, meta, _ = store.restore_latest(t0)
    assert meta["step"] == 1
    assert_tree_equal(state, t0)


def test_snapshot_gc_keeps_newest(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    for s in range(5):
        store.save(tree(s), step=s)
    assert store.snapshots() == [3, 4]


def test_restore_none_when_empty(tmp_path):
    store = CheckpointStore(str(tmp_path))
    assert store.restore_latest(tree()) is None


def test_process_crash_recovery(tmp_path):
    """A fresh store object on the same dir recovers snapshot + journal."""
    d = str(tmp_path)
    s1 = CheckpointStore(d)
    s1.save(tree(5), step=7, offsets={0: 70})
    s1.record_step(8, offsets={0: 80})
    s1.journal.close()
    s2 = CheckpointStore(d)  # "new process"
    state, meta, events = s2.restore_latest(tree(0))
    assert meta["step"] == 7
    assert [e.data["step"] for e in events] == [8]
    assert s2.latest_offsets() == {0: 80}

"""Event-sourced checkpoint store: atomicity, restore, journal replay,
corruption fallback, GC — plus sharded manifests, the async write-behind
worker, and the live state-handoff channel."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.handoff import StateHandoffChannel
from repro.checkpoint.store import (
    CheckpointStore,
    _compress,
    load_pytree,
    merge_shards,
    pack_shard,
    plan_shards,
    save_pytree,
    shard_axes_from_shardings,
)
from repro.data.topics import MessageLog


def tree(seed=0):
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 3)
    return {
        "a": jax.random.normal(ks[0], (4, 8)),
        "nested": {"b": jax.random.normal(ks[1], (3,), dtype=jnp.bfloat16),
                   "c": jnp.asarray(7, dtype=jnp.int32)},
    }


def assert_tree_equal(x, y):
    for a, b in zip(jax.tree.leaves(x), jax.tree.leaves(y)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pytree_roundtrip(tmp_path):
    t = tree()
    path = str(tmp_path / "x.ckpt")
    save_pytree(t, path, meta={"step": 3})
    loaded, meta = load_pytree(t, path)
    assert meta["step"] == 3
    assert_tree_equal(t, loaded)
    assert loaded["nested"]["b"].dtype == jnp.bfloat16


def test_codec_tagged_roundtrip_and_fallback(tmp_path):
    """Snapshots are codec-tagged: zlib files load regardless of whether
    zstandard is installed, and asking for zstd without the lib is a clear
    error instead of a corrupt file."""
    from repro.checkpoint.store import default_codec, zstd

    t = tree()
    p_zlib = str(tmp_path / "zl.ckpt")
    save_pytree(t, p_zlib, meta={"codec": "zlib"}, codec="zlib")
    loaded, meta = load_pytree(t, p_zlib)
    assert meta["codec"] == "zlib"
    assert_tree_equal(t, loaded)
    with open(p_zlib, "rb") as fh:
        assert fh.read(4) == b"RLZL"
    if zstd is not None:
        p_zstd = str(tmp_path / "zs.ckpt")
        save_pytree(t, p_zstd, meta={}, codec="zstd")
        loaded, _ = load_pytree(t, p_zstd)
        assert_tree_equal(t, loaded)
        assert default_codec() == "zstd"
    else:
        assert default_codec() == "zlib"
        with pytest.raises(RuntimeError):
            save_pytree(t, str(tmp_path / "zs.ckpt"), codec="zstd")
    with pytest.raises(ValueError):
        save_pytree(t, str(tmp_path / "x.ckpt"), codec="lz4")


def test_store_restore_latest_with_journal(tmp_path):
    store = CheckpointStore(str(tmp_path))
    t0, t1 = tree(0), tree(1)
    store.save(t0, step=10, offsets={0: 100, 1: 90})
    store.record_step(11, offsets={0: 110, 1: 95}, metrics={"loss": 3.2})
    store.save(t1, step=12, offsets={0: 120, 1: 100})
    store.record_step(13, offsets={0: 130, 1: 105}, metrics={"loss": 3.0})
    state, meta, events = store.restore_latest(t0)
    assert meta["step"] == 12
    assert_tree_equal(state, t1)
    assert [e.data["step"] for e in events] == [13]
    assert store.latest_offsets() == {0: 130, 1: 105}


def test_corrupt_snapshot_falls_back(tmp_path):
    store = CheckpointStore(str(tmp_path))
    t0, t1 = tree(0), tree(1)
    store.save(t0, step=1)
    p2 = store.save(t1, step=2)
    with open(p2, "wb") as fh:
        fh.write(b"garbage")  # simulate a torn write
    state, meta, _ = store.restore_latest(t0)
    assert meta["step"] == 1
    assert_tree_equal(state, t0)


def test_snapshot_gc_keeps_newest(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    for s in range(5):
        store.save(tree(s), step=s)
    assert store.snapshots() == [3, 4]


def test_restore_none_when_empty(tmp_path):
    store = CheckpointStore(str(tmp_path))
    assert store.restore_latest(tree()) is None


def test_process_crash_recovery(tmp_path):
    """A fresh store object on the same dir recovers snapshot + journal."""
    d = str(tmp_path)
    s1 = CheckpointStore(d)
    s1.save(tree(5), step=7, offsets={0: 70})
    s1.record_step(8, offsets={0: 80})
    s1.journal.close()
    s2 = CheckpointStore(d)  # "new process"
    state, meta, events = s2.restore_latest(tree(0))
    assert meta["step"] == 7
    assert [e.data["step"] for e in events] == [8]
    assert s2.latest_offsets() == {0: 80}


# ---------------------------------------------------------------------------
# sharded manifests
# ---------------------------------------------------------------------------


def test_truncated_latest_snapshot_falls_back(tmp_path):
    """A kill mid-write can only tear the tmp file (atomic rename), but
    disk faults can still truncate the newest snapshot after the fact —
    restore must fall back, not crash or half-load."""
    store = CheckpointStore(str(tmp_path))
    t0, t1 = tree(0), tree(1)
    store.save(t0, step=1)
    p2 = store.save(t1, step=2)
    with open(p2, "r+b") as fh:
        fh.truncate(os.path.getsize(p2) // 2)
    state, meta, _ = store.restore_latest(t0)
    assert meta["step"] == 1
    assert_tree_equal(state, t0)


def test_truncated_shard_falls_back(tmp_path):
    """Sharded form of the same fault: a truncated shard breaks its
    manifest digest, so the whole sharded snapshot is rejected."""
    store = CheckpointStore(str(tmp_path), shards=2)
    t0, t1 = tree(0), tree(1)
    store.save(t0, step=1)
    store.save(t1, step=2)
    spath = store._shard_path(2, 0, 2)
    with open(spath, "r+b") as fh:
        fh.truncate(os.path.getsize(spath) // 2)
    state, meta, _ = store.restore_latest(t0)
    assert meta["step"] == 1
    assert_tree_equal(state, t0)


def test_missing_shard_falls_back(tmp_path):
    store = CheckpointStore(str(tmp_path), shards=3)
    t0, t1 = tree(0), tree(1)
    store.save(t0, step=1)
    store.save(t1, step=2)
    os.remove(store._shard_path(2, 1, 3))
    state, meta, _ = store.restore_latest(t0)
    assert meta["step"] == 1
    assert_tree_equal(state, t0)


@pytest.mark.parametrize("k", [1, 2, 3, 5])
def test_shard_layout_independent_merge(k):
    """plan/pack at any shard count k; merge reassembles bitwise — the
    primitive behind save-at-DP-k / load-at-DP-j."""
    t = tree(3)
    leaves = [np.asarray(x) for x in jax.tree.leaves(t)]
    plan = plan_shards(leaves, k)
    raws = [pack_shard(leaves, entries) for entries in plan]
    merged = merge_shards(t, raws)
    assert_tree_equal(t, merged)


def test_save_at_k_load_at_j_via_store(tmp_path):
    """A store built with a different shard count reads any manifest —
    the shard layout is a property of the *file set*, not the reader."""
    t = tree(4)
    w = CheckpointStore(str(tmp_path), shards=3)
    w.save(t, step=9)
    w.journal.close()
    for j in (1, 2, 4):
        r = CheckpointStore(str(tmp_path), shards=j)
        state, meta, _ = r.restore_latest(tree(0))
        assert meta["step"] == 9
        assert_tree_equal(t, state)
        r.journal.close()


def test_zoo_sharded_config_save_load_bitwise(tmp_path):
    """Satellite property: a zoo arch's real train state, shard axes
    derived from its live ``param_shardings`` assignment, saved sharded
    and restored bitwise (the shard boundary follows the PartitionSpec's
    first sharded dim, not a blanket axis 0)."""
    from repro.config import TrainingConfig, get_arch
    from repro.distributed.elastic_mesh import mesh_for_devices
    from repro.distributed.param_shardings import (
        make_rules,
        train_state_shardings,
    )
    from repro.models.zoo import build_model
    from repro.training.train_step import init_train_state

    cfg = get_arch("llama3.2-1b", smoke=True)
    tcfg = TrainingConfig(
        learning_rate=1e-3, warmup_steps=0, schedule="constant"
    )
    model = build_model(cfg, compute_dtype=jnp.float32)
    state = init_train_state(model, tcfg, jax.random.PRNGKey(0))
    mesh = mesh_for_devices(jax.device_count())
    rules = make_rules(cfg, mesh)
    shardings = train_state_shardings(state, cfg, mesh, rules)
    axes = shard_axes_from_shardings(shardings)
    assert len(axes) == len(jax.tree.leaves(state))

    w = CheckpointStore(str(tmp_path), shards=4)
    w.save(state, step=1, shard_axes=axes)
    w.journal.close()
    r = CheckpointStore(str(tmp_path), shards=2)  # "load at DP=j, j != k"
    template = jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype), state)
    restored, meta, _ = r.restore_latest(template)
    assert meta["step"] == 1
    assert_tree_equal(state, restored)
    r.journal.close()


def test_keep_last_gc_is_manifest_aware(tmp_path):
    """GC on a sharded store removes whole snapshot *sets* (manifest
    first, then its shards) and never strands a manifest whose shards
    were deleted."""
    store = CheckpointStore(str(tmp_path), keep_last=2, shards=2)
    for s in range(5):
        store.save(tree(s), step=s)
    assert store.snapshots() == [3, 4]
    names = set(os.listdir(str(tmp_path)))
    for s in (0, 1, 2):
        assert f"manifest-{s:010d}.json" not in names
        assert f"shard-{s:010d}-000of002.ckpt" not in names
    # every surviving manifest is fully backed by its shard files
    import json as _json
    for s in (3, 4):
        with open(store._manifest_path(s)) as fh:
            manifest = _json.load(fh)
        for rec in manifest["shards"]:
            assert rec["file"] in names
    state, meta, _ = store.restore_latest(tree(0))
    assert meta["step"] == 4
    assert_tree_equal(state, tree(4))


# ---------------------------------------------------------------------------
# async write-behind
# ---------------------------------------------------------------------------


def test_async_save_ticket_then_restore(tmp_path):
    store = CheckpointStore(str(tmp_path), shards=2, async_io=True)
    t = tree(6)
    ticket = store.save_async(t, step=5, extra={"stream": {"rr": 0}})
    ticket.wait(30.0)
    assert store.async_saves == 1 and store.sync_saves == 0
    state, meta, _ = store.restore_latest(tree(0))
    assert meta["step"] == 5 and meta["stream"] == {"rr": 0}
    assert_tree_equal(t, state)
    store.close()


def test_async_journal_gate_and_flush(tmp_path):
    """While the write-behind worker is paused the journal line is
    *submitted but not durable* (ticket pending); a fresh store sees
    nothing.  After resume+flush the line is durable everywhere."""
    store = CheckpointStore(str(tmp_path), async_io=True)
    store.writer.pause()
    store.record_step(1, offsets={0: 10})
    ticket = store.last_write_ticket()
    assert ticket is not None and not ticket.done()
    probe = CheckpointStore(str(tmp_path))
    assert probe.latest_offsets() == {}
    probe.journal.close()
    store.writer.resume()
    store.flush()
    assert ticket.done() and ticket.error is None
    probe2 = CheckpointStore(str(tmp_path))
    assert probe2.latest_offsets() == {0: 10}
    probe2.journal.close()
    store.close()


def test_write_behind_kill_discards_queued_writes(tmp_path):
    """Process death with writes still queued: tickets error, nothing
    lands, and a rebuilt store sees exactly the pre-crash directory."""
    store = CheckpointStore(str(tmp_path), async_io=True)
    store.save_async(tree(0), step=1).wait(30.0)  # durable baseline
    store.writer.pause()
    store.record_step(2, offsets={0: 20})
    t_snap = store.save_async(tree(1), step=2)
    lost = store.kill()
    assert lost >= 1
    assert t_snap.done() and t_snap.error is not None
    rebuilt = CheckpointStore(str(tmp_path))
    state, meta, _ = rebuilt.restore_latest(tree(9))
    assert meta["step"] == 1          # the queued step-2 snapshot never landed
    assert_tree_equal(state, tree(0))
    assert rebuilt.latest_offsets() == {}  # nor did its journal line
    rebuilt.journal.close()


# ---------------------------------------------------------------------------
# live state handoff
# ---------------------------------------------------------------------------


def test_state_handoff_roundtrip_and_delta_suppression():
    log = MessageLog()
    ch = StateHandoffChannel(log, shards=2, codec="zlib")
    t0 = tree(0)
    ch.publish_state(t0, step=3, meta={"stream": {"rr": 1}})
    got = StateHandoffChannel(log, shards=2).latest_state(tree(9))
    assert got is not None
    state, meta, deltas = got
    assert meta["step"] == 3 and meta["stream"] == {"rr": 1}
    assert_tree_equal(t0, state)
    assert deltas == []
    # identical republish: every shard digest matches -> all suppressed,
    # and a reader resolves the suppressed shards from the earlier epoch
    out = ch.publish_state(t0, step=4)
    assert out == {"streamed": 0, "suppressed": 2}
    state2, meta2, _ = StateHandoffChannel(log, shards=2).latest_state(tree(9))
    assert meta2["step"] == 4
    assert_tree_equal(t0, state2)


def test_state_handoff_torn_epoch_ignored():
    """A publisher killed between its shard records and the commit
    record must not poison the channel: the reader resolves the newest
    *complete* epoch."""
    log = MessageLog()
    ch = StateHandoffChannel(log, shards=2, codec="zlib")
    t0 = tree(0)
    ch.publish_state(t0, step=3)
    # epoch 1 dies mid-stream: one shard record, no commit
    leaves = [np.asarray(x) for x in jax.tree.leaves(tree(1))]
    blob = _compress(pack_shard(leaves, plan_shards(leaves, 2)[0]), "zlib")
    import base64
    from repro.checkpoint.store import content_digest
    ch._publish({"kind": "shard", "epoch": 1, "k": 0,
                 "digest": content_digest(blob),
                 "data": base64.b64encode(blob).decode("ascii")})
    state, meta, _ = StateHandoffChannel(log, shards=2).latest_state(tree(9))
    assert meta["step"] == 3
    assert_tree_equal(t0, state)


def test_state_handoff_deltas_measure_catchup():
    log = MessageLog()
    ch = StateHandoffChannel(log, shards=1, codec="zlib")
    ch.publish_state(tree(0), step=5)
    ch.publish_delta(6, {"offsets": {"0": 48}})
    ch.publish_delta(7, {"offsets": {"0": 56}})
    _, meta, deltas = ch.latest_state(tree(9))
    assert meta["step"] == 5
    assert [d["step"] for d in deltas] == [6, 7]

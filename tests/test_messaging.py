"""Messaging + virtual-messaging layer: Kafka semantics, the Liquid task
limit, and the Reactive decoupling that removes it (the paper's core claim
at the mechanism level)."""

import pytest
from _hypothesis_support import given, settings, st

from repro.core.messages import Mailbox, MailboxOverflow, Message, MessageBus
from repro.core.scheduler import (
    JoinShortestQueueScheduler,
    PowerOfTwoScheduler,
    RoundRobinScheduler,
    make_scheduler,
)
from repro.core.state import EventJournal
from repro.core.virtual_messaging import (
    VirtualConsumerGroup,
    VirtualProducerGroup,
    VirtualTopic,
)
from repro.data.topics import ConsumerGroup, MessageLog, Topic


def make_topic(n_messages=30, partitions=3, name="in") -> Topic:
    t = Topic(name, partitions)
    for i in range(n_messages):
        t.publish(Message(topic=name, payload=i))
    return t


# --- messaging layer ---------------------------------------------------------


def test_partition_order_and_offsets():
    t = make_topic(30, 3)
    for p in t.partitions:
        msgs = p.read(0, 100)
        assert [m.offset for m in msgs] == list(range(len(msgs)))
        # round-robin publish => payload stride == partition count
        payloads = [m.payload for m in msgs]
        assert payloads == sorted(payloads)


def test_keyed_messages_land_in_one_partition():
    t = Topic("keyed", 4)
    for i in range(20):
        t.publish(Message(topic="keyed", payload=i, key="same-key"))
    non_empty = [p for p in t.partitions if len(p) > 0]
    assert len(non_empty) == 1
    assert len(non_empty[0]) == 20


def test_consumer_group_member_limit():
    """Kafka semantics: at most num_partitions members receive work."""
    t = make_topic(30, 3)
    g = ConsumerGroup("g", t)
    assert g.active_members(6) == 3  # the Liquid limitation (Fig. 2)
    assignment = g.assign(6)
    assert set(assignment.values()) == {0, 1, 2}


def test_at_least_once_redelivery():
    t = make_topic(10, 1)
    g = ConsumerGroup("g", t)
    c = g.consumer_for(0)
    first = c.poll(5)
    assert len(first) == 5
    c.rewind_to_committed()  # crash before commit
    again = c.poll(5)
    assert [m.payload for m in again] == [m.payload for m in first]
    c.commit()
    rest = c.poll(100)
    assert len(rest) == 5


def test_mailbox_backpressure():
    box = Mailbox("t", capacity=2)
    box.put(Message(topic="x", payload=1))
    box.put(Message(topic="x", payload=2))
    with pytest.raises(MailboxOverflow):
        box.put(Message(topic="x", payload=3))
    assert box.dropped == 1


def test_message_bus_location_transparency():
    bus = MessageBus()
    bus.register("worker-1")
    assert bus.send("worker-1", Message(topic="t", payload=1))
    assert not bus.send("worker-404", Message(topic="t", payload=2))
    assert bus.dead_letter_count() == 1
    # re-home the address: senders don't change
    bus.unregister("worker-1")
    fresh = bus.register("worker-1")
    assert bus.send("worker-1", Message(topic="t", payload=3))
    assert fresh.depth() == 1


# --- virtual messaging layer ---------------------------------------------------


def test_tasks_scale_past_partitions():
    """THE core mechanism: 3 partitions, 8 tasks, all 8 receive work."""
    t = make_topic(64, 3)
    group = VirtualConsumerGroup("job", t, batch_size=8)
    queues = [Mailbox(f"task{i}") for i in range(8)]
    while group.step_all(queues) > 0:
        pass
    assert group.total_lag() == 0
    depths = [q.enqueued for q in queues]
    assert all(d > 0 for d in depths), depths
    assert sum(depths) == 64


def test_virtual_consumer_count_capped_at_partitions():
    t = make_topic(10, 3)
    group = VirtualConsumerGroup("job", t)
    assert len(group.consumers) == 3  # bounded by the log, as in the paper


def test_virtual_consumer_restart_resumes_from_committed_offset(tmp_path):
    t = make_topic(40, 1)
    journals = {}

    def journal_factory(partition):
        journals[partition] = EventJournal(str(tmp_path / f"vc{partition}.jsonl"))
        return journals[partition]

    group = VirtualConsumerGroup(
        "job", t, batch_size=10, journal_factory=journal_factory
    )
    queues = [Mailbox("task0")]
    group.step_all(queues)
    assert group.consumers[0].offset == 10
    # Let-It-Crash: rebuild the consumer; journal replay restores the offset.
    journals[0].close()
    vc2 = group.restart_consumer(0)
    assert vc2.offset == 10
    group.step_all(queues)
    assert vc2.offset == 20
    # No duplicates were forwarded.
    payloads = []
    while True:
        m = queues[0].get()
        if m is None:
            break
        payloads.append(m.payload)
    assert payloads == list(range(20))


def test_backpressure_stops_forwarding_and_commits_prefix():
    t = make_topic(20, 1)
    group = VirtualConsumerGroup("job", t, batch_size=10)
    tiny = [Mailbox("task0", capacity=3)]
    group.step_all(tiny)
    assert group.consumers[0].offset == 3  # only the delivered prefix commits
    # drain and continue
    for _ in range(3):
        tiny[0].get()
    group.step_all(tiny)
    assert group.consumers[0].offset == 6


def test_virtual_producer_group_balances_and_publishes():
    out = Topic("out", 2)
    pg = VirtualProducerGroup(out, initial_size=3)
    for i in range(12):
        pg.submit(Message(topic="out", payload=i))
    per_producer = [p.inbox.depth() for p in pg.producers]
    assert per_producer == [4, 4, 4]  # round-robin balance
    pg.step_all()
    assert out.total_messages() == 12
    # scale-in drains victims into survivors
    for i in range(4):
        pg.submit(Message(topic="out", payload=100 + i))
    pg.resize(1)
    assert pg.pending() == 4
    pg.step_all()
    assert out.total_messages() == 16


# --- schedulers ---------------------------------------------------------------


class _Q:
    def __init__(self, d):
        self._d = d

    def depth(self):
        return self._d


def test_round_robin_cycles():
    s = RoundRobinScheduler()
    qs = [_Q(0)] * 4
    assert [s.pick(qs) for _ in range(6)] == [0, 1, 2, 3, 0, 1]


def test_jsq_picks_minimum():
    s = JoinShortestQueueScheduler()
    assert s.pick([_Q(5), _Q(2), _Q(9), _Q(2)]) == 1  # min, lowest index tie


@given(st.lists(st.integers(0, 100), min_size=2, max_size=16), st.integers(0, 1000))
def test_pow2_never_picks_strictly_worse_than_both_samples(depths, seed):
    qs = [_Q(d) for d in depths]
    s = PowerOfTwoScheduler(seed=seed)
    for _ in range(20):
        i = s.pick(qs)
        assert 0 <= i < len(depths)


@settings(max_examples=25)
@given(st.integers(2, 12), st.integers(50, 200), st.integers(0, 10))
def test_jsq_balances_better_than_rr_with_heterogeneous_drain(n, msgs, seed):
    """With one stuck queue, JSQ avoids it; RR keeps feeding it."""
    import random

    rng = random.Random(seed)
    stuck = rng.randrange(n)

    def run(sched):
        boxes = [Mailbox(f"q{i}") for i in range(n)]
        for _ in range(msgs):
            idx = sched.pick(boxes)
            boxes[idx].put(Message(topic="t", payload=0))
            for j, b in enumerate(boxes):  # everyone but `stuck` drains
                if j != stuck:
                    b.get()
        return boxes[stuck].depth()

    assert run(JoinShortestQueueScheduler()) <= run(RoundRobinScheduler())


def test_pow2_prefers_shorter_queue_smoke():
    """Deterministic pow2 check; runs without hypothesis."""
    s = PowerOfTwoScheduler(seed=0)
    qs = [_Q(50), _Q(0), _Q(50), _Q(50)]
    picks = [s.pick(qs) for _ in range(32)]
    assert all(0 <= i < 4 for i in picks)
    # whenever queue 1 is sampled it wins; over 32 picks it must show up
    assert picks.count(1) > 0


def test_make_scheduler_registry():
    assert make_scheduler("round_robin").name == "round_robin"
    assert make_scheduler("jsq").name == "jsq"
    assert make_scheduler("pow2").name == "pow2"
    with pytest.raises(ValueError):
        make_scheduler("nope")

"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, assert output shapes + finiteness. One decode-path test per family.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_arch, list_archs
from repro.models.zoo import build_model

pytestmark = pytest.mark.slow  # heavy sweep/compile module: excluded from tier-1

ARCHS = [
    "gemma3-4b",
    "minicpm-2b",
    "llama3.2-1b",
    "command-r-plus-104b",
    "mixtral-8x7b",
    "llama4-maverick-400b-a17b",
    "internvl2-1b",
    "jamba-v0.1-52b",
    "whisper-tiny",
    "mamba2-370m",
]

B, S = 2, 32


def make_batch(cfg, rng):
    k1, k2, k3 = jax.random.split(rng, 3)
    batch = {
        "tokens": jax.random.randint(k1, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(k2, (B, S), 0, cfg.vocab_size),
    }
    if cfg.encoder_layers > 0:
        batch["frontend"] = jax.random.normal(
            k3, (B, cfg.encoder_seq, cfg.d_model), dtype=jnp.float32
        )
    elif cfg.frontend_tokens > 0:
        batch["frontend"] = jax.random.normal(
            k3, (B, cfg.frontend_tokens, cfg.d_model), dtype=jnp.float32
        )
    return batch


def test_all_ten_archs_registered():
    assert set(ARCHS) <= set(list_archs())


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finiteness(arch):
    cfg = get_arch(arch, smoke=True)
    model = build_model(cfg, compute_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    logits, aux = jax.jit(model.train_logits)(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_moves_loss(arch):
    """One SGD step reduces (or at least changes) the loss, grads finite."""
    cfg = get_arch(arch, smoke=True)
    model = build_model(cfg, compute_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    def loss(p):
        l, _ = model.loss_fn(p, batch)
        return l

    l0, grads = jax.jit(jax.value_and_grad(loss))(params)
    assert np.isfinite(float(l0))
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0
    lr = 0.5 / max(float(gnorm), 1.0)
    params2 = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
    l1 = jax.jit(loss)(params2)
    assert np.isfinite(float(l1))
    assert float(l1) < float(l0)


@pytest.mark.parametrize(
    "arch", ["llama3.2-1b", "mixtral-8x7b", "jamba-v0.1-52b", "whisper-tiny",
             "mamba2-370m", "gemma3-4b"]
)
def test_prefill_then_decode_matches_full_forward(arch):
    """Decode with KV cache must reproduce the full-sequence logits."""
    cfg = get_arch(arch, smoke=True)
    model = build_model(cfg, compute_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    rng = jax.random.PRNGKey(7)
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    frontend = None
    if cfg.encoder_layers > 0:
        frontend = jax.random.normal(
            jax.random.PRNGKey(9), (B, cfg.encoder_seq, cfg.d_model),
            dtype=jnp.float32,
        )
    batch = {"tokens": toks}
    if frontend is not None:
        batch["frontend"] = frontend

    full_logits, _ = model.train_logits(params, batch)

    # prefill on the first S-1 tokens, decode the last one
    cache = model.init_cache(B, S)
    pre = {"tokens": toks[:, : S - 1]}
    if frontend is not None:
        pre["frontend"] = frontend
    _, cache = model.prefill(params, pre, cache)
    positions = jnp.full((B,), S - 1, dtype=jnp.int32)
    step_logits, _ = model.decode_step(
        params, toks[:, S - 1 :], cache, positions, frontend=frontend
    )
    np.testing.assert_allclose(
        np.asarray(step_logits[:, 0]),
        np.asarray(full_logits[:, -1]),
        rtol=2e-3,
        atol=2e-3,
    )


def test_vlm_frontend_changes_logits():
    cfg = get_arch("internvl2-1b", smoke=True)
    model = build_model(cfg, compute_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    f1 = jax.random.normal(jax.random.PRNGKey(2), (B, cfg.frontend_tokens, cfg.d_model))
    f2 = f1 + 1.0
    l1, _ = model.train_logits(params, {"tokens": toks, "frontend": f1})
    l2, _ = model.train_logits(params, {"tokens": toks, "frontend": f2})
    assert l1.shape == (B, S, cfg.vocab_size)  # logits only on text positions
    assert not np.allclose(np.asarray(l1), np.asarray(l2))


def test_param_counts_match_scale():
    """Full configs should land near their nameplate parameter counts."""
    cases = {
        "llama3.2-1b": (0.9e9, 1.9e9),
        "mixtral-8x7b": (40e9, 56e9),
        "command-r-plus-104b": (90e9, 120e9),
        "mamba2-370m": (0.2e9, 0.6e9),
        "llama4-maverick-400b-a17b": (230e9, 480e9),
    }
    for arch, (lo, hi) in cases.items():
        n = get_arch(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.1f}B outside [{lo/1e9},{hi/1e9}]B"

"""Quickstart: the paper's full pipeline in ~60 lines.

Streams synthetic taxi trajectories through the Reactive Liquid stack —
messaging layer -> virtual messaging -> elastic task pool -> TCMM
micro-clustering job -> change-event topic -> macro-clustering job — and
prints what happened, including a mid-stream task crash that the
supervisor heals.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

from repro.apps.tcmm import MacroClusterJob, MicroClusterJob
from repro.configs.tcmm import TCMMConfig
from repro.core.reactive import ReactiveJob
from repro.data.sources import TrajectorySource
from repro.data.topics import MessageLog

N_POINTS = 1200

def main() -> None:
    # 1. Messaging layer: two topics, three partitions each (as in §4.3).
    log = MessageLog()
    log.create_topic("trajectories", 3)
    log.create_topic("micro-changes", 3)
    for key, point in TrajectorySource(num_taxis=50, seed=0).stream(N_POINTS):
        log.publish("trajectories", payload=point, key=key)

    # 2. Processing layer: the paper's two TCMM jobs, wired through the
    #    virtual messaging layer with an elastic task pool.
    cfg = TCMMConfig(max_micro_clusters=256, distance_threshold=4.0,
                     num_macro_clusters=6, macro_period=256)
    micro, macro = MicroClusterJob(cfg), MacroClusterJob(cfg)
    micro_job = ReactiveJob("micro", log, "trajectories", micro,
                            out_topic="micro-changes", initial_tasks=4,
                            scheduler="jsq", heartbeat_timeout=3.0)
    macro_job = ReactiveJob("macro", log, "micro-changes", macro,
                            initial_tasks=2, heartbeat_timeout=3.0)

    # 3. Run; kill a task mid-stream — Let-It-Crash heals it.
    killed = False
    t = 0.0
    while micro_job.backlog() or macro_job.backlog() or t == 0.0:
        t += 1.0
        micro_job.step(now=t)
        macro_job.step(now=t)
        if not killed and micro.state.processed > N_POINTS // 3:
            victim = micro_job.tasks[0]
            victim.alive = False
            print(f"[t={t:.0f}] killed {victim.name} (processed so far: "
                  f"{micro.state.processed})")
            killed = True
        if t > 10_000:
            break

    restarts = [e for e in micro_job.supervisor.events if e[1] == "restarted"]
    print(f"processed:       {micro.state.processed}/{N_POINTS} trajectories")
    print(f"micro-clusters:  {micro.state.num_active}")
    print(f"macro runs:      {macro.macro_runs} "
          f"(centers shape {None if macro.macro_centers is None else macro.macro_centers.shape})")
    print(f"task pool size:  {len(micro_job.tasks)} (elastic)")
    print(f"supervisor:      {len(restarts)} restart(s) — pipeline healed")
    assert micro.state.processed == N_POINTS
    assert restarts, "supervisor should have healed the killed task"
    print("OK")


if __name__ == "__main__":
    main()

"""End-to-end LM training on the Reactive Liquid data path.

Trains a (reduced-config) llama3.2 on synthetic token streams fed through
the virtual messaging layer, with event-sourced checkpoints.  Pass
``--full-size`` on real hardware for the 1B config; the defaults are
CPU-sized so the example finishes in ~a minute.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 100]
"""

import sys

from repro.launch.train import main

if __name__ == "__main__":
    argv = sys.argv[1:] or [
        "--arch", "llama3.2-1b",
        "--steps", "60",
        "--batch-size", "8",
        "--seq-len", "64",
        "--checkpoint-dir", "/tmp/repro-train-lm",
        "--checkpoint-every", "20",
    ]
    raise SystemExit(main(argv))

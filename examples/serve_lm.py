"""Reactive elastic serving of a (reduced-config) model: a traffic spike
flows through the bounded request mailbox into autoscaled batcher
replicas — the slot-unit target rides the spike up (spawning a second
replica over the shared ingress) and drains back down after it.  Requests
route to replicas via a load-aware admission policy (JSQ by default).

Run:  PYTHONPATH=src python examples/serve_lm.py
Try:  PYTHONPATH=src python examples/serve_lm.py --stub --spike \
          --requests 120 --kill-replica 0      # chaos drill, instant
      PYTHONPATH=src python examples/serve_lm.py --stub --spike \
          --requests 120 --log-backed          # same traffic through the
                                               # durable requests topic
"""

import sys

from repro.launch.serve import main

if __name__ == "__main__":
    argv = sys.argv[1:] or ["--arch", "llama3.2-1b", "--requests", "24",
                            "--slots", "4", "--max-new-tokens", "10",
                            "--spike"]
    raise SystemExit(main(argv))

"""Continuous-batched serving of a (reduced-config) model: a burst of
requests with ragged prompt lengths flows through the request mailbox into
decode slots; slots free on completion and admit the next request.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import sys

from repro.launch.serve import main

if __name__ == "__main__":
    argv = sys.argv[1:] or ["--arch", "llama3.2-1b", "--requests", "24",
                            "--slots", "4", "--max-new-tokens", "10"]
    raise SystemExit(main(argv))

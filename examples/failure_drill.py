"""Failure drill: Let-It-Crash at the PROCESS level.

Launches a real training worker process that hard-crashes (os._exit) at
step 15; the process supervisor detects the death, relaunches with
--resume, and the worker rebuilds from the event-sourced checkpoint —
losses continue from where they stopped and the data stream resumes at
the exact committed offsets (no skipped or re-trained batches).

Run:  PYTHONPATH=src python examples/failure_drill.py
"""

import json
import shutil
import tempfile

from repro.launch.cluster import ProcessSupervisor, WorkerSpec


def main() -> None:
    workdir = tempfile.mkdtemp(prefix="repro-drill-")
    ckpt = f"{workdir}/ckpt"
    hb = f"{workdir}/heartbeat"
    spec = WorkerSpec(
        name="trainer-0",
        heartbeat_file=hb,
        args=[
            "--arch", "llama3.2-1b",
            "--steps", "30",
            "--batch-size", "4",
            "--seq-len", "32",
            "--checkpoint-dir", ckpt,
            "--checkpoint-every", "5",
            "--crash-at-step", "15",   # the drill
            "--log-every", "5",
        ],
    )
    sup = ProcessSupervisor(spec, heartbeat_timeout=60.0, max_restarts=2)
    code = sup.run(total_timeout=600.0)

    print("\n--- supervision log ---")
    for ev in sup.events:
        print(f"  {ev.kind:10s} {ev.worker} {ev.detail}")
    assert code == 0, f"drill failed with exit {code}"
    assert sup.restarts >= 1, "worker should have crashed and restarted"
    kinds = [e.kind for e in sup.events]
    assert "suspected" in kinds and "restarted" in kinds and "finished" in kinds
    print(f"\nOK: crashed once, supervisor healed it, training finished. "
          f"(workdir: {workdir})")
    shutil.rmtree(workdir, ignore_errors=True)


if __name__ == "__main__":
    main()

"""Elastic scaling demo: a bursty workload drives the autoscaler.

A trajectory stream arrives in waves; the elastic worker service watches
the task mailboxes and scales the TCMM task pool out under each burst and
back in when the backlog drains — the paper's "react to changes in
workload by increasing or decreasing the resources".

Run:  PYTHONPATH=src python examples/elastic_scaling.py
"""

from repro.apps.tcmm import MicroClusterJob
from repro.configs.tcmm import TCMMConfig
from repro.core.elastic import AutoscalerConfig
from repro.core.reactive import ReactiveJob
from repro.data.sources import TrajectorySource
from repro.data.topics import MessageLog


def main() -> None:
    log = MessageLog()
    log.create_topic("trajectories", 4)
    src = TrajectorySource(num_taxis=40, seed=1)
    stream = src.stream(10_000)

    job = ReactiveJob(
        "micro", log, "trajectories", MicroClusterJob(TCMMConfig()),
        initial_tasks=2, scheduler="jsq", batch_n=32,
        autoscaler=AutoscalerConfig(
            high_watermark=24, low_watermark=2,
            min_workers=2, max_workers=16, cooldown=3.0,
        ),
    )

    sizes = []
    t = 0.0
    for phase, burst in enumerate([40, 400, 40, 600, 0, 0, 0]):
        for _ in range(10):  # 10 ticks per phase
            t += 1.0
            for _ in range(burst // 10):
                try:
                    key, p = next(stream)
                except StopIteration:
                    break
                log.publish("trajectories", payload=p, key=key)
            job.step(now=t, task_budget=4)
            sizes.append(len(job.tasks))
        print(f"phase {phase} (burst={burst:4d}/tick x10): "
              f"tasks={len(job.tasks):3d} backlog={job.backlog():5d}")

    # drain
    while job.backlog():
        t += 1.0
        job.step(now=t, task_budget=4)
    for _ in range(5):
        t += 1.0
        job.step(now=t)

    print(f"\npeak pool size: {max(sizes)} (started at 2)")
    print(f"final pool size after drain: {len(job.tasks)}")
    print(f"scale events: {len(job.pool.controller.scale_events)}")
    assert max(sizes) > 2, "should have scaled out under the bursts"
    assert len(job.tasks) < max(sizes), "should have scaled back in"
    print("OK")


if __name__ == "__main__":
    main()
